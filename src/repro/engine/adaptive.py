"""Adaptive query execution: every stage boundary is a re-optimization
point (Skyrise-style adaptivity over the paper's §3.2 coordinator).

The static ``Coordinator`` compiles a whole plan up front and schedules
it; cardinality misestimates are locked in before the first byte moves.
``AdaptiveCoordinator`` instead drives the plan stage-at-a-time and, at
each boundary, revises the not-yet-run suffix against what the finished
stages *actually* produced:

  * **fan-out / tier re-derivation** — the next shuffle's partition count
    is re-derived from observed producer bytes (``optimizer.derive_fanout``,
    the same rule lowering used on estimates) and its exchange tier is
    re-placed through the measured break-even model
    (``breakeven.place_exchange_from_bench``);
  * **build-side flip** — when the observed build input of a shuffle join
    turns out larger than the probe side, the sides swap and a
    key-restoring rename projection keeps the downstream schema intact;
  * **elided-join demotion** — a join whose shuffle was elided because a
    base table *declared* a hash-partitioned layout is probed with the
    summarized runtime check (``worker.partition_class_bitmap``); a lying
    layout gets an explicit repartition scan injected instead of the
    fail-loud abort the static path hits.

Every decision is appended to the result's ``adaptive_trace`` as an
``adaptive:`` line (rendered by ``engine.explain``) and counted in
``QueryResult.replans``.

Straggler speculation replaces the static size-based timeout: a fragment
whose modeled duration crosses the *expected max-of-m barrier* from the
paper's Table 5 lognormal tail model (``variability.cov_sigma``) gets a
duplicate launched. Duplicates are provably idempotent — fragment
execution is deterministic, so the duplicate re-puts byte-identical
shuffle objects under identical keys and re-records the same partition
bitmap in ``worker.ShuffleRegistry``; first writer wins and nothing
downstream can tell which copy it read.

Fault recovery differs by policy: ``repair="targeted"`` (adaptive)
audits producer bitmaps against storage at the boundary and re-executes
only the writer fragments whose objects are missing; ``repair="stage"``
(the static baseline) discovers the loss when a consumer read fails and
re-executes every producer stage in full. Under ``core.chaos`` injection
the gap between the two is what the ``adaptive_chaos`` bench gates at
p99.
"""
from __future__ import annotations

import copy
import dataclasses
import math
from typing import Optional

from repro.core import breakeven, storage_service, variability
from repro.core.scheduler import Stage, StageResult, StageScheduler, \
    StragglerPolicy
from repro.engine import columnar, logical, optimizer, worker
from repro.engine import compile as engine_compile
from repro.engine import plans as plans_mod
from repro.engine.coordinator import Coordinator, QueryResult
from repro.engine.plans import (Pipeline, QueryPlan, ShuffleInput,
                                ShuffleOutput, TableInput)


def expected_max_multiplier(m: int, cov_percent: float,
                            safety: float = 1.2) -> float:
    """Barrier multiplier for speculation: the expected max of ``m``
    concurrent lognormal draws at the given runtime CoV sits near the
    m/(m+1) quantile, ``exp(sigma * probit(m/(m+1)))`` relative to the
    median. A fragment slower than ``safety`` times that is beyond what
    the tail model explains — duplicate it. Small stages still use the
    m=4 quantile so a lone fragment's ordinary noise never speculates."""
    sigma = variability.cov_sigma(cov_percent)
    m = max(int(m), 4)
    q = m / (m + 1.0)
    return safety * math.exp(sigma * storage_service._probit(q))


@dataclasses.dataclass(frozen=True)
class AdaptivePolicy:
    """Which boundary revisions the adaptive executor may take.

    ``ADAPTIVE`` enables everything with targeted fault repair;
    ``STATIC`` disables every revision and repairs by coarse lineage
    re-execution — the honest model of the static coordinator under
    faults, and the baseline the chaos bench compares against."""

    replan_fanout: bool = True
    replan_tier: bool = True
    flip_build: bool = True
    demote_elided: bool = True
    speculate: bool = True
    repair: str = "targeted"            # "targeted" | "stage"
    flip_factor: float = 1.1            # observed build/probe ratio to flip
    # Paper Table 5, us-east-1 cold-suite CoV: the tail model the
    # speculation barrier is derived from.
    barrier_cov_percent: float = 22.65
    barrier_safety: float = 1.2
    max_recover_attempts: int = 2
    # Lineage recovery: how many times ONE fragment may be re-attempted
    # in place after a worker kill/OOM before the failure escalates to a
    # stage re-run (and then, past ``max_recover_attempts``, to a
    # structured query-level failure). The static baseline has no
    # in-place retry — every kill costs it a stage re-run.
    max_fragment_attempts: int = 3
    # Cap on speculative duplicates per stage (None = unlimited); denied
    # launches surface as ``speculative_denied`` in pool stats.
    max_speculative: Optional[int] = None


ADAPTIVE = AdaptivePolicy()
STATIC = AdaptivePolicy(replan_fanout=False, replan_tier=False,
                        flip_build=False, demote_elided=False,
                        speculate=False, repair="stage")


class SpeculativeStageScheduler(StageScheduler):
    """Stage scheduler whose straggler mitigation is model-driven
    speculation: instead of the static size-based timeout, a fragment
    that crosses the lognormal expected-max barrier launches a REAL
    duplicate execution (``frag.work()`` again). Duplicate re-puts are
    byte-identical under identical keys, so first writer wins through
    the shuffle registry's partition bitmaps; the fragment completes at
    whichever copy finishes first in model time."""

    def __init__(self, pool, policy: StragglerPolicy = StragglerPolicy(),
                 straggler_prob: float = 0.02, rng_seed: int = 0,
                 chaos=None, barrier_cov_percent: float = 22.65,
                 barrier_safety: float = 1.2,
                 max_fragment_attempts: int = 3,
                 max_speculative: Optional[int] = None):
        super().__init__(pool, policy, straggler_prob, rng_seed, chaos=chaos)
        self.barrier_cov_percent = barrier_cov_percent
        self.barrier_safety = barrier_safety
        self.max_fragment_attempts = max_fragment_attempts
        self.max_speculative = max_speculative
        # Recovery trace sink: the adaptive coordinator points this at the
        # current query's trace list so ``recover:`` lines reach explain.
        self.trace: Optional[list] = None

    def _run_stage(self, stage: Stage, t: float) -> StageResult:
        n = len(stage.fragments)
        workers = self.pool.acquire(n, t)
        results: list[object] = [None] * n
        end = t
        launched = won = recovered = 0
        node_seconds = 0.0
        mult = expected_max_multiplier(n, self.barrier_cov_percent,
                                       self.barrier_safety)
        try:
            for i, (frag, w) in enumerate(zip(stage.fragments, workers)):
                start = w.ready_at
                attempt = 0
                # Lineage recovery, in place: a killed attempt is charged
                # its modeled duration, then ONLY the dead attempt re-runs
                # under a new attempt number (an OOM kill re-runs with the
                # chaos threshold as its memory budget, so the retry takes
                # the spill path). Past the attempt budget the kill
                # escalates to the coordinator's stage-level ladder.
                while True:
                    try:
                        if attempt == 0 or frag.rerun is None:
                            results[i] = frag.work()
                        else:
                            results[i] = frag.rerun(**rerun_kwargs)
                        break
                    except worker.WorkerKilled as exc:
                        dead = self._noisy_duration(frag.est_duration_s)
                        if self.chaos is not None:
                            dead *= self.chaos.slow_multiplier(
                                stage.name, frag.fragment_id,
                                attempt=attempt)
                        node_seconds += dead
                        start += dead
                        end = max(end, start)
                        attempt += 1
                        if frag.rerun is None or \
                                attempt >= self.max_fragment_attempts:
                            raise
                        recovered += 1
                        rerun_kwargs = {"attempt": attempt}
                        if isinstance(exc, worker.WorkerOOMKilled):
                            rerun_kwargs["memory_budget"] = \
                                float(exc.threshold_bytes)
                        if self.trace is not None:
                            how = ("with a "
                                   f"{exc.threshold_bytes / 2**20:.1f} "
                                   "MiB spill budget"
                                   if isinstance(exc,
                                                 worker.WorkerOOMKilled)
                                   else "under a fresh attempt key")
                            self.trace.append(
                                f"recover: fragment {frag.fragment_id} of "
                                f"'{stage.name}' killed ({exc.kind}, "
                                f"attempt {attempt - 1}); re-ran only the "
                                f"dead attempt {how}")
                dur = self._noisy_duration(frag.est_duration_s)
                if self.chaos is not None:
                    dur *= self.chaos.slow_multiplier(stage.name,
                                                      frag.fragment_id,
                                                      attempt=attempt)
                completion = start + dur
                node_seconds += dur
                barrier = frag.est_duration_s * mult
                if frag.est_duration_s > 0 and dur > barrier:
                    if self.max_speculative is not None \
                            and launched >= self.max_speculative:
                        self.pool.stats["speculative_denied"] = \
                            self.pool.stats.get("speculative_denied", 0) + 1
                    else:
                        # Beyond the expected max of n draws: duplicate
                        # the fragment for real (idempotent; see class
                        # docstring) and race it against the original.
                        launched += 1
                        frag.work()
                        dup = self._noisy_duration(frag.est_duration_s)
                        if self.chaos is not None:
                            # The duplicate is a fresh invocation: it
                            # draws its own chaos slowdown (attempt-
                            # keyed), independent of whatever slowed the
                            # original.
                            dup *= self.chaos.slow_multiplier(
                                stage.name, frag.fragment_id,
                                attempt=attempt + 1)
                        dup_completion = start + barrier + dup
                        node_seconds += min(dup, max(0.0, dur - barrier))
                        if dup_completion < completion:
                            completion = dup_completion
                            won += 1
                end = max(end, completion)
        except Exception as exc:
            # Escalation: charge what ran, release the fleet, surface the
            # elapsed model time for the stage-level recovery ladder.
            elapsed_end = max(end, t)
            self.pool.release(workers, elapsed_end,
                              busy_s=node_seconds / max(n, 1))
            exc.elapsed_s = max(0.0, elapsed_end - t)
            exc.node_seconds = node_seconds
            raise
        self.pool.release(workers, end, busy_s=node_seconds / max(n, 1))
        return StageResult(stage.name, t, end, n, results,
                           retried_fragments=launched,
                           node_seconds=node_seconds,
                           speculative_launched=launched,
                           speculative_won=won,
                           recovered_attempts=recovered)


class AdaptiveCoordinator(Coordinator):
    """Coordinator that executes stage-at-a-time, revising the plan
    suffix at every stage boundary (module docstring). ``policy=STATIC``
    turns every revision off and degrades fault repair to full lineage
    re-execution — the chaos bench's baseline — while keeping the
    identical compile/schedule path, so the two variants differ only in
    the adaptive decisions themselves."""

    def __init__(self, store, policy: AdaptivePolicy = ADAPTIVE,
                 rng_seed: int = 0, chaos=None, **kw):
        super().__init__(store, rng_seed=rng_seed, chaos=chaos, **kw)
        self.policy = policy
        if policy.speculate:
            self.scheduler = SpeculativeStageScheduler(
                self.pool, StragglerPolicy(), rng_seed=rng_seed,
                chaos=chaos,
                barrier_cov_percent=policy.barrier_cov_percent,
                barrier_safety=policy.barrier_safety,
                max_fragment_attempts=policy.max_fragment_attempts,
                max_speculative=policy.max_speculative)

    # ------------------------------------------------------------------
    def execute(self, plan: QueryPlan, query_id: Optional[str] = None
                ) -> QueryResult:
        plan.validate()
        query_id = query_id or plan.name
        plan = copy.deepcopy(plan)    # boundary revisions mutate the plan
        shape_hash, cache_hit = "", False
        if self.backend == "jit":
            shape_hash, cache_hit = engine_compile.PLAN_CACHE.lookup(plan)
        stats_before = dataclasses.replace(self.store.stats)
        kv_stats_before = dataclasses.replace(self.kv_store.stats)
        registry = worker.ShuffleRegistry()
        frag_counts: dict[str, int] = {}
        shuffle_spec: dict[str, int] = {}
        tier_spec: dict[str, str] = {}
        stages: dict[str, Stage] = {}
        results: dict[str, StageResult] = {}
        trace: list[str] = []
        # Injected repartition scans have no plan deps; they still cannot
        # start before the boundary at which demotion was decided.
        min_start: dict[str, float] = {}
        self._replan_count = 0
        if hasattr(self.scheduler, "trace"):
            self.scheduler.trace = trace
        idx = 0
        while idx < len(plan.pipelines):
            pipe = plan.pipelines[idx]
            boundary_t = max([0.0] + [results[d].end_t
                                      for d in pipe.deps() if d in results])
            # --- the stage boundary: re-optimization point -------------
            self._replan(plan, idx, query_id, registry, frag_counts,
                         shuffle_spec, tier_spec, results, trace)
            if plan.pipelines[idx] is not pipe:    # demotion inserted a scan
                pipe = plan.pipelines[idx]
                min_start[pipe.name] = boundary_t
            repair_dur = 0.0
            if self.policy.repair == "targeted":
                repair_dur = self._repair_lost(pipe, query_id, registry,
                                               frag_counts, tier_spec,
                                               stages, results, trace)
            stage = self._compile_pipeline(plan, pipe, query_id, registry,
                                           frag_counts, shuffle_spec,
                                           tier_spec)
            stages[pipe.name] = stage
            start = max([min_start.get(pipe.name, 0.0)] +
                        [results[d].end_t for d in stage.deps]) + repair_dur
            results[pipe.name] = self._run_with_recovery(
                plan, pipe, stage, start, stages, results, trace,
                query_id=query_id, registry=registry,
                frag_counts=frag_counts, shuffle_spec=shuffle_spec,
                tier_spec=tier_spec)
            idx += 1
        return self.finalize(plan, query_id, frag_counts, results,
                             stats_before, shape_hash, cache_hit,
                             kv_stats_before=kv_stats_before,
                             adaptive_trace=trace,
                             replans=self._replan_count)

    # -- fault recovery -------------------------------------------------
    def _run_with_recovery(self, plan: QueryPlan, pipe: Pipeline,
                           stage: Stage, start: float,
                           stages: dict[str, Stage],
                           results: dict[str, StageResult],
                           trace: list[str], *, query_id: str,
                           registry: worker.ShuffleRegistry,
                           frag_counts: dict[str, int],
                           shuffle_spec: dict[str, int],
                           tier_spec: dict[str, str]) -> StageResult:
        """Stage-level rung of the recovery ladder (above the in-place
        attempt retries of ``SpeculativeStageScheduler``, below the
        structured query failure):

        * **worker kill / OOM / failed invocation** — the stage's objects
          are intact (a killed attempt never commits), so only the stage
          itself re-runs;
        * **store brownout** (``UnavailableError`` / an open circuit
          breaker) — every kv exchange this stage touches (its own output
          and its deps') is demoted to the object store, the affected
          producers re-run under the demoted placement, and the stage is
          recompiled before the retry — brownout, not outage;
        * **anything else** (a lost shuffle write surfacing at read time)
          — coarse lineage recovery: every producer stage re-executes in
          full, then the stage retries.

        Past ``policy.max_recover_attempts`` the failure surfaces as a
        ``QueryFailedError`` carrying a structured failure record."""
        from repro.engine.coordinator import QueryFailedError
        attempts = 0
        while True:
            try:
                return self.scheduler.run_stage(stage, start)
            except RuntimeError as exc:
                attempts += 1
                start += getattr(exc, "elapsed_s", 0.0)
                if attempts > self.policy.max_recover_attempts:
                    raise QueryFailedError(query_id, stage.name, attempts,
                                           exc) from exc
                if isinstance(exc, (storage_service.UnavailableError,
                                    storage_service.CircuitOpenError)):
                    demoted = self._demote_kv_exchanges(
                        plan, pipe, stage, start, stages, results,
                        query_id=query_id, registry=registry,
                        frag_counts=frag_counts,
                        shuffle_spec=shuffle_spec, tier_spec=tier_spec)
                    if demoted:
                        stage = self._compile_pipeline(
                            plan, pipe, query_id, registry, frag_counts,
                            shuffle_spec, tier_spec)
                        stages[pipe.name] = stage
                        self._replan_count += 1
                        trace.append(
                            f"recover: kv exchange browned out under "
                            f"stage '{stage.name}'; demoted "
                            f"{demoted} shuffle placement(s) to the "
                            "object store and retried")
                        continue
                if isinstance(exc, worker.WorkerKilled) or not stage.deps:
                    # The dead attempt never committed: inputs are
                    # intact, so only this stage re-runs.
                    trace.append(
                        f"recover: stage '{stage.name}' lost a worker "
                        f"({getattr(exc, 'kind', 'crash')}); re-ran the "
                        f"stage (attempt {attempts + 1})")
                    continue
                # Coarse lineage recovery (the static baseline): the
                # failed read cannot name which producer fragment lost a
                # write, so every producer stage re-executes in full
                # before the retry.
                rec_end = start
                for dep in stage.deps:
                    rres = self.scheduler.run_stage(stages[dep], start)
                    prev = results[dep]
                    prev.node_seconds += rres.node_seconds
                    prev.retried_fragments += rres.worker_count
                    rec_end = max(rec_end, rres.end_t)
                trace.append(
                    f"recovery: stage '{stage.name}' hit a lost shuffle "
                    f"write; re-executed producer stage(s) "
                    f"{list(stage.deps)} in full and retried ({exc})")
                start = rec_end

    def _demote_kv_exchanges(self, plan: QueryPlan, pipe: Pipeline,
                             stage: Stage, start: float,
                             stages: dict[str, Stage],
                             results: dict[str, StageResult], *,
                             query_id: str,
                             registry: worker.ShuffleRegistry,
                             frag_counts: dict[str, int],
                             shuffle_spec: dict[str, int],
                             tier_spec: dict[str, str]) -> int:
        """Move every kv exchange the failed stage touches onto the
        object store: the stage's own output placement flips, and any dep
        whose shuffle lives on the dark tier is re-produced under the
        demoted placement (idempotent re-execution, commits unchanged).
        Returns the number of demoted placements."""
        demoted = 0
        if isinstance(pipe.output, ShuffleOutput) \
                and pipe.output.tier == "kv":
            pipe.output.tier = "object"
            tier_spec[pipe.name] = "object"
            demoted += 1
        for dep in stage.deps:
            if tier_spec.get(dep) != "kv" or dep not in stages:
                continue
            dep_pipe = next(p for p in plan.pipelines if p.name == dep)
            if isinstance(dep_pipe.output, ShuffleOutput):
                dep_pipe.output.tier = "object"
            tier_spec[dep] = "object"
            dep_stage = self._compile_pipeline(plan, dep_pipe, query_id,
                                               registry, frag_counts,
                                               shuffle_spec, tier_spec)
            stages[dep] = dep_stage
            rres = self.scheduler.run_stage(dep_stage, start)
            prev = results[dep]
            prev.node_seconds += rres.node_seconds
            prev.retried_fragments += rres.worker_count
            prev.end_t = max(prev.end_t, rres.end_t)
            demoted += 1
        return demoted

    def _repair_lost(self, pipe: Pipeline, query_id: str,
                     registry: worker.ShuffleRegistry,
                     frag_counts: dict[str, int],
                     tier_spec: dict[str, str],
                     stages: dict[str, Stage],
                     results: dict[str, StageResult],
                     trace: list[str]) -> float:
        """Targeted repair: audit each producer's partition bitmap
        against storage before its consumer compiles; re-execute only the
        writer fragments whose recorded objects are missing. Duplicate
        re-execution is idempotent (deterministic byte-identical re-puts),
        so a healthy writer re-run is harmless and a lost one is healed.
        Returns the model-time delay the repair adds before the consumer
        can start."""
        repair_dur = 0.0
        for dep in pipe.deps():
            if dep not in stages:
                continue
            st = self._tier_store(tier_spec.get(dep, "object"))
            lost = []
            for w in range(frag_counts[dep]):
                bm = registry.bitmap(query_id, dep, w) or 0
                att = registry.committed_attempt(query_id, dep, w) or 0
                part = 0
                while bm:
                    if bm & 1:
                        key = worker.shuffle_key(query_id, dep, w, part,
                                                 attempt=att)
                        try:
                            st.size(key)
                        except KeyError:
                            lost.append(w)
                            break
                    bm >>= 1
                    part += 1
            if not lost:
                continue
            durs = []
            res = results[dep]
            for w in lost:
                frag = stages[dep].fragments[w]
                att = registry.committed_attempt(query_id, dep, w) or 0
                # First writer wins; the re-put is byte-identical. The
                # duplicate re-runs the COMMITTED attempt so the healed
                # object lands under the key readers will resolve.
                if att and frag.rerun is not None:
                    frag.rerun(attempt=att)
                else:
                    frag.work()
                dur = self.scheduler._noisy_duration(frag.est_duration_s)
                if self.chaos is not None:
                    dur *= self.chaos.slow_multiplier(dep, w, attempt=2)
                durs.append(dur)
                res.node_seconds += dur
                res.speculative_launched += 1
                res.speculative_won += 1
            repair_dur = max(repair_dur, max(durs))
            trace.append(
                f"adaptive: recovered {len(lost)} lost shuffle write(s) "
                f"of '{dep}' by targeted duplicate re-execution before "
                f"stage '{pipe.name}' (first writer wins)")
        return repair_dur

    # -- boundary re-planning -------------------------------------------
    def _replan(self, plan: QueryPlan, idx: int, query_id: str,
                registry: worker.ShuffleRegistry,
                frag_counts: dict[str, int], shuffle_spec: dict[str, int],
                tier_spec: dict[str, str],
                results: dict[str, StageResult],
                trace: list[str]) -> None:
        pipe = plan.pipelines[idx]
        if self.policy.demote_elided and self._maybe_demote(plan, idx,
                                                            trace):
            return    # pipelines[idx] is now the injected repartition scan
        if self.policy.flip_build:
            self._maybe_flip(plan, pipe, query_id, registry, frag_counts,
                             tier_spec, results, trace)
        if isinstance(pipe.output, ShuffleOutput) \
                and (self.policy.replan_fanout or self.policy.replan_tier):
            self._maybe_replace_exchange(plan, idx, query_id, registry,
                                         frag_counts, shuffle_spec,
                                         tier_spec, trace)

    def _observed_shuffle_bytes(self, query_id: str, name: str,
                                frag_counts: dict[str, int],
                                registry: worker.ShuffleRegistry,
                                tier_spec: dict[str, str]) -> float:
        """Bytes a finished producer actually shuffled, summed over the
        objects its writers' bitmaps recorded. A recorded-but-missing
        object (lost write) is skipped here; the repair pass owns it."""
        st = self._tier_store(tier_spec.get(name, "object"))
        total = 0.0
        for w in range(frag_counts.get(name, 0)):
            bm = registry.bitmap(query_id, name, w) or 0
            att = registry.committed_attempt(query_id, name, w) or 0
            part = 0
            while bm:
                if bm & 1:
                    try:
                        total += st.size(worker.shuffle_key(
                            query_id, name, w, part, attempt=att))
                    except KeyError:
                        pass
                bm >>= 1
                part += 1
        return total

    def _observed_input_bytes(self, pipe: Pipeline, query_id: str,
                              frag_counts: dict[str, int],
                              registry: worker.ShuffleRegistry,
                              tier_spec: dict[str, str]
                              ) -> Optional[float]:
        if isinstance(pipe.input, TableInput):
            keys = self.table_keys.get(pipe.input.table, [])
            return float(sum(self.store.size(k) for k in keys))
        src = pipe.input.from_pipeline
        if src not in frag_counts:
            return None
        return self._observed_shuffle_bytes(query_id, src, frag_counts,
                                            registry, tier_spec)

    @staticmethod
    def _scale_for_ops(est: float, pipe: Pipeline) -> float:
        """The lowering's output-size heuristics, applied to an observed
        input instead of a table estimate — so the re-derived fan-out is
        the planner's own rule evaluated on truth."""
        for op in pipe.ops:
            kind = op.get("op")
            if kind == "filter":
                est *= optimizer.FILTER_SELECTIVITY
            elif kind == "hash_agg":
                est *= optimizer.AGG_OUTPUT_FRACTION
        return est

    @staticmethod
    def _consumers(plan: QueryPlan, name: str) -> list[Pipeline]:
        out = []
        for c in plan.pipelines:
            for inp in (c.input, c.input2):
                if isinstance(inp, ShuffleInput) \
                        and inp.from_pipeline == name:
                    out.append(c)
                    break
        return out

    def _refanout_feasible(self, plan: QueryPlan, pipe: Pipeline,
                           frag_counts: dict[str, int]) -> bool:
        """A producer's fan-out may change only while every consumer —
        and every co-partitioned partner feeding the same join — is still
        un-compiled and un-pinned, so the whole co-partition group moves
        together."""
        consumers = self._consumers(plan, pipe.name)
        if not consumers:
            return False
        for c in consumers:
            if c.fragments is not None or isinstance(c.input2, TableInput) \
                    or c.name in frag_counts:
                return False
            for other in (c.input, c.input2):
                if isinstance(other, ShuffleInput) \
                        and other.from_pipeline != pipe.name:
                    if other.from_pipeline in frag_counts:
                        return False    # partner already ran at old fan-out
                    for cc in self._consumers(plan, other.from_pipeline):
                        if cc is not c:
                            return False
        return True

    def _maybe_replace_exchange(self, plan: QueryPlan, idx: int,
                                query_id: str,
                                registry: worker.ShuffleRegistry,
                                frag_counts: dict[str, int],
                                shuffle_spec: dict[str, int],
                                tier_spec: dict[str, str],
                                trace: list[str]) -> None:
        pipe = plan.pipelines[idx]
        out = pipe.output
        observed = self._observed_input_bytes(pipe, query_id, frag_counts,
                                              registry, tier_spec)
        if not observed:
            return
        est_out = self._scale_for_ops(observed, pipe)
        global_agg = any(op.get("op") == "hash_agg" and not op.get("keys")
                         for op in pipe.ops)
        if self.policy.replan_fanout and not global_agg:
            new = optimizer.derive_fanout(
                est_out, self.backend, memory_budget=self.memory_budget)
            if new != out.partitions \
                    and self._refanout_feasible(plan, pipe, frag_counts):
                old = out.partitions
                consumers = self._consumers(plan, pipe.name)
                partners = []
                for c in consumers:
                    for other in (c.input, c.input2):
                        if isinstance(other, ShuffleInput) \
                                and other.from_pipeline != pipe.name:
                            p2 = next(p for p in plan.pipelines
                                      if p.name == other.from_pipeline)
                            if isinstance(p2.output, ShuffleOutput) \
                                    and p2 not in partners:
                                partners.append(p2)
                out.partitions = new
                srcs = {pipe.name}
                for p2 in partners:
                    p2.output.partitions = new
                    srcs.add(p2.name)
                for c in plan.pipelines:
                    for inp, attr in ((c.input, "partitioning"),
                                      (c.input2, "partitioning2")):
                        part = getattr(c, attr)
                        if part and isinstance(inp, ShuffleInput) \
                                and inp.from_pipeline in srcs:
                            setattr(c, attr, {**part, "fanout": new})
                plan.validate()
                self._replan_count += 1
                trace.append(
                    f"adaptive: re-derived fan-out of '{pipe.name}' "
                    f"shuffle from observed {observed / 2**20:.1f} MiB "
                    f"input: {old} -> {new} partitions"
                    + (f" (co-partitioned with "
                       f"{sorted(p.name for p in partners)})"
                       if partners else ""))
        if self.policy.replan_tier:
            writers, _ = self._parallelism(pipe, frag_counts, query_id,
                                           shuffle_spec)
            placed = breakeven.place_exchange_from_bench(
                est_out, writers, out.partitions)
            target = placed.tier
            breaker = getattr(self.kv_store, "breaker", None)
            if target == "kv" and breaker is not None \
                    and breaker.state != "closed":
                # The kv tier's circuit is open (or probing): break-even
                # or not, new placements stay off the dark tier.
                trace.append(
                    f"adaptive: kv tier circuit {breaker.state}; pinned "
                    f"'{pipe.name}' exchange to the object store")
                target = "object"
            if target != out.tier:
                old_tier = out.tier
                out.tier = target
                self._replan_count += 1
                trace.append(
                    f"adaptive: moved '{pipe.name}' exchange {old_tier} "
                    f"-> {target} tier at observed "
                    f"{est_out / 2**20:.1f} MiB (break-even re-placement)")

    def _maybe_flip(self, plan: QueryPlan, pipe: Pipeline, query_id: str,
                    registry: worker.ShuffleRegistry,
                    frag_counts: dict[str, int],
                    tier_spec: dict[str, str],
                    results: dict[str, StageResult],
                    trace: list[str]) -> None:
        """Flip a shuffle join's build side when the observed sizes
        inverted the planner's estimate. Only un-elided joins qualify
        (both sides ShuffleInput, no relied partitioning): the inputs are
        co-partitioned on the join keys, so swapping which side builds
        the hash table is local to each fragment. A rename projection
        restores the planned output schema (the probe-side key name
        survives a join, and after the flip that is the other key)."""
        join_ops = [op for op in pipe.ops if op.get("op") == "hash_join"]
        if len(join_ops) != 1 or pipe.join is not None:
            return
        if not (isinstance(pipe.input, ShuffleInput)
                and isinstance(pipe.input2, ShuffleInput)):
            return
        if pipe.partitioning2 is not None:
            return
        # A relied input partitioning (a downstream shuffle was elided
        # against the join's co-partitioning) survives a flip: the sides
        # are equi-join co-partitioned at one fan-out, so fragment i
        # holds key class i either way — only the property's key NAME
        # follows the new probe producer's partition key.
        probe_src = pipe.input.from_pipeline
        build_src = pipe.input2.from_pipeline
        if probe_src not in results or build_src not in results:
            return
        probe_b = self._observed_shuffle_bytes(query_id, probe_src,
                                               frag_counts, registry,
                                               tier_spec)
        build_b = self._observed_shuffle_bytes(query_id, build_src,
                                               frag_counts, registry,
                                               tier_spec)
        if probe_b <= 0 or build_b <= probe_b * self.policy.flip_factor:
            return
        op = join_ops[0]
        a, b = op["left_key"], op["right_key"]
        schemas = plans_mod.pipeline_schemas(plan)
        probe_schema = schemas.get(probe_src)
        build_schema = schemas.get(build_src)
        if probe_schema is None or build_schema is None:
            trace.append(
                f"adaptive: build side of '{pipe.name}' observed "
                f"{build_b / 2**20:.1f} MiB > probe "
                f"{probe_b / 2**20:.1f} MiB, but an opaque upstream op "
                "hides the schema; kept planned sides")
            return
        out_schema = logical.join_output_schema(probe_schema, build_schema,
                                                b)
        pipe.input, pipe.input2 = pipe.input2, pipe.input
        op["left_key"], op["right_key"] = b, a
        if pipe.partitioning is not None:
            new_prod = next(p for p in plan.pipelines
                            if p.name == pipe.input.from_pipeline)
            pipe.partitioning = {**pipe.partitioning,
                                 "key": new_prod.output.partition_by}
        j = pipe.ops.index(op)
        pipe.ops.insert(
            j + 1,
            {"op": "project",
             "columns": [c if c != a else [a, b] for c in out_schema]})
        plan.validate()
        self._replan_count += 1
        trace.append(
            f"adaptive: flipped build side of '{pipe.name}': planned "
            f"build '{build_src}' observed {build_b / 2**20:.1f} MiB vs "
            f"probe '{probe_src}' {probe_b / 2**20:.1f} MiB; now "
            f"building on '{probe_src}'")

    def _maybe_demote(self, plan: QueryPlan, idx: int,
                      trace: list[str]) -> bool:
        """Demote an elided co-partition join whose *declared* base-table
        layout lies: probe each stored partition slice with the
        summarized ``key % fanout`` bitmap check and, on a violation,
        inject an explicit repartition scan in front of the join instead
        of letting the worker's fail-loud validation abort the stage.
        The probe reads are billed to the store like any other request;
        they overlap planning in model time."""
        pipe = plan.pipelines[idx]
        demoted = False
        for side, inp, part in (("probe", pipe.input, pipe.partitioning),
                                ("build", pipe.input2, pipe.partitioning2)):
            if not (isinstance(inp, TableInput) and part):
                continue
            key, fanout = part["key"], part["fanout"]
            keys = self.table_keys.get(inp.table, [])
            if len(keys) != fanout:
                continue    # _parallelism raises its own error for this
            violated = None
            for i, k in enumerate(keys):
                batch = columnar.deserialize(self.store.get(k), [key])
                bm = worker.partition_class_bitmap(batch, key, fanout)
                if bm & ~(1 << i):
                    violated = i
                    break
            if violated is None:
                continue
            scan_name = f"{pipe.name}__repart_{side}"
            while any(p.name == scan_name for p in plan.pipelines):
                scan_name += "_"
            plan.pipelines.insert(idx, Pipeline(
                name=scan_name,
                input=TableInput(inp.table, list(inp.columns)),
                ops=[],
                output=ShuffleOutput(key, fanout)))
            if side == "probe":
                pipe.input = ShuffleInput(scan_name)
                pipe.partitioning = None
                pipe.fragments = None
            else:
                pipe.input2 = ShuffleInput(scan_name)
                pipe.partitioning2 = None
            demoted = True
            self._replan_count += 1
            trace.append(
                f"adaptive: demoted elided co-partition join in "
                f"'{pipe.name}': stored partition {violated} of table "
                f"'{inp.table}' holds keys outside class {violated} "
                f"(hash({key}) % {fanout}); injected repartition scan "
                f"'{scan_name}'")
        if demoted:
            plan.validate()
        return demoted
