"""Skyrise serverless query engine (paper §3.2): a shared-storage engine
whose coordinator and workers are stateless tasks communicating only
through the object store, runnable in 'elastic' (FaaS) or 'provisioned'
(IaaS) mode with identical physical plans."""
from repro.engine import (columnar, compile, coordinator,  # noqa: F401
                          datagen, operators, plans, queries, worker)
