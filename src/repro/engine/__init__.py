"""Skyrise serverless query engine (paper §3.2): a shared-storage engine
whose coordinator and workers are stateless tasks communicating only
through the object store, runnable in 'elastic' (FaaS) or 'provisioned'
(IaaS) mode with identical physical plans.

Public API: author queries with the logical builder (``scan``/``col``/
``lit`` plus the aggregate helpers), hand the resulting ``LogicalQuery``
to ``Coordinator.run`` (which optimizes and lowers it), or lower it
yourself via ``engine.optimizer``. ``QueryPlan`` remains the physical
interchange format. ``python -m repro.engine.explain <query>`` shows a
query's logical plan, the applied optimizer rules, and the physical
pipelines.

Execution runs on the compiled ``jit`` backend by default;
``backend="numpy"`` selects the interpreted float64 semantic reference.
``docs/BACKENDS.md`` documents the backend contract (float tolerances,
the remaining jit->numpy fallback cases, forcing a backend per query);
``docs/ARCHITECTURE.md`` is the full engine walkthrough (logical
builder -> optimizer -> physical plans -> compiled kernels).
"""
from repro.engine import (columnar, compile, coordinator,  # noqa: F401
                          datagen, logical, operators, optimizer,
                          plans, queries, worker)
from repro.engine.coordinator import Coordinator
from repro.engine.logical import (col, count_, lit, max_, min_, scan,
                                  sum_)
from repro.engine.plans import QueryPlan


def __getattr__(name):
    # ``explain`` loads lazily so ``python -m repro.engine.explain``
    # doesn't trip runpy's already-imported warning.
    if name == "explain":
        import importlib
        return importlib.import_module("repro.engine.explain")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    # primary entry points
    "Coordinator", "QueryPlan",
    # logical builder
    "scan", "col", "lit", "sum_", "count_", "min_", "max_",
    # modules
    "columnar", "compile", "coordinator", "datagen", "explain", "logical",
    "operators", "optimizer", "plans", "queries", "worker",
]
