"""Optimizing planner: lowers the logical IR to physical pipelines.

Rule-based passes over ``engine.logical`` trees, in order:

1. **Predicate pushdown** — filter conjuncts move through projections
   (rewriting renamed columns) and join sides down into the scans, so
   workers drop rows before shuffling them (Lambada's first lesson: pay
   object-store I/O for as few bytes as possible).
2. **Projection pruning** — every scan's column list narrows to exactly
   the columns referenced above it; bare ``scan("t")`` column lists are
   inferred. UDF stages without a declared output schema keep their
   explicit scan columns.
3. **Aggregate split** — each ``hash_agg`` becomes a per-fragment partial
   aggregate in the producing pipeline plus a final re-aggregation after
   a combine shuffle; count partials re-aggregate as sums
   (``logical.FINAL_AGG_FN``). This retires the hand-rolled
   ``__zero__`` single-partition shuffle idiom: the combine shuffle
   partitions by the first group key (or the first aggregate output for
   global aggregates — any column works at fan-out 1).
4. **Physical choices** — the join build side is the smaller estimated
   input (probe keeps its storage order and the build side is the one
   held in memory); shuffle fan-out is chosen so one partition is about
   ``TARGET_PARTITION_SECONDS`` of work at the measured
   ``core.bench_profile`` throughput (falling back to hand-set
   constants), clamped to [1, MAX_SHUFFLE_PARTITIONS]. An explicit
   ``LogicalQuery.shuffle_partitions`` hint pins the fan-out of ROW
   shuffles (join co-partitioning); aggregate-combine shuffles are
   optimizer-owned — the partial agg already shrank the data, so the
   combine follows its own (small) estimate, and a global aggregate's
   combine is always 1 partition (its partition key is a partial value,
   not a grouping key).

The emitted ``plans.QueryPlan`` uses only today's physical vocabulary, so
the numpy and jit backends (including the fused join->ops->partition
trace) run lowered plans unchanged. ``lower`` returns the plan plus a
``PlanReport`` recording every applied rule (rendered by
``engine.explain``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core import bench_profile
from repro.engine import logical
from repro.engine.logical import (Aggregate, Filter, Join, LogicalError,
                                  LogicalQuery, Project, Scan, Udf)
from repro.engine.plans import (CollectOutput, Pipeline, QueryPlan,
                                ShuffleInput, ShuffleOutput, TableInput)

MIB = 1024.0 ** 2

# Physical-choice knobs. Fallback throughputs mirror the coordinator's
# hand-set constants; when BENCH_engine.json is present the measured
# numbers win (core.bench_profile).
FALLBACK_CPU_BYTES_PER_S = {"numpy": 600e6, "jit": 1.5e9}
TARGET_PARTITION_SECONDS = 0.25
MAX_SHUFFLE_PARTITIONS = 64
DEFAULT_SHUFFLE_PARTITIONS = 8      # no stats, no hint
FILTER_SELECTIVITY = 0.2            # default per-filter row survival
AGG_OUTPUT_FRACTION = 0.05          # partial-agg output / input estimate
AGG_EST_OUTPUT_BYTES = 1.0 * MIB    # fallback when the input is unsized


@dataclasses.dataclass
class Stats:
    """Planner-visible table statistics (bytes on the object store)."""
    table_bytes: dict[str, float] = dataclasses.field(default_factory=dict)

    @staticmethod
    def from_store(store, table_keys: dict[str, list[str]]) -> "Stats":
        out = {}
        for table, keys in table_keys.items():
            try:
                out[table] = float(sum(store.size(k) for k in keys))
            except KeyError:
                continue
        return Stats(out)

    def bytes_for(self, table: str) -> Optional[float]:
        return self.table_bytes.get(table)


@dataclasses.dataclass
class PlanReport:
    """What the optimizer did: the rewritten logical tree plus one line
    per applied rule, in application order."""
    name: str
    rules: list[str]
    logical_root: object


# ---------------------------------------------------------------------------
# Pass 1: predicate pushdown
# ---------------------------------------------------------------------------

def _conjuncts(pred: list) -> list[list]:
    return list(pred[1:]) if pred[0] == "and" else [pred]


def _combine(preds: list[list]) -> list:
    return preds[0] if len(preds) == 1 else ["and"] + preds


def _rename_pred(expr: list, m: dict) -> list:
    op = expr[0]
    if op in ("and", "or"):
        return [op] + [_rename_pred(e, m) for e in expr[1:]]
    if op == "ltcol":
        return [op, m[expr[1]], m[expr[2]]]
    return [op, m[expr[1]]] + list(expr[2:])


def _wrap(node, stuck: list[tuple[list, bool]]):
    if not stuck:
        return node
    return Filter(node, _combine([p for p, _ in stuck]))


def _pushdown(node, preds: list[tuple[list, bool]], trace: list[str]):
    """Place each (predicate, crossed-a-boundary) pair as deep as it can
    go; record a rule line whenever a crossed predicate lands on a scan."""
    if isinstance(node, Filter):
        mine = [(c, False) for c in _conjuncts(node.predicate)]
        return _pushdown(node.child, preds + mine, trace)
    if isinstance(node, Scan):
        if not preds:
            return node
        crossed = sum(1 for _, c in preds if c)
        if crossed:
            trace.append(f"predicate_pushdown: {crossed} conjunct(s) "
                         f"pushed into scan({node.table})")
        return Filter(node, _combine([p for p, _ in preds]))
    if isinstance(node, Project):
        bindings = {}
        for c in node.columns:
            if isinstance(c, str):
                bindings[c] = c
            elif isinstance(c[1], str):
                bindings[c[0]] = c[1]           # pure rename
        pushable, stuck = [], []
        for p, crossed in preds:
            cols = logical.pred_columns(p)
            if cols <= set(bindings):
                pushable.append((_rename_pred(p, bindings), True))
            else:
                stuck.append((p, crossed))
        out = Project(_pushdown(node.child, pushable, trace), node.columns)
        return _wrap(out, stuck)
    if isinstance(node, Join):
        ls, rs = logical.schema(node.left), logical.schema(node.right)
        left, right, stuck = [], [], []
        for p, crossed in preds:
            cols = logical.pred_columns(p)
            if ls is not None and cols <= set(ls):
                left.append((p, True))
            elif rs is not None and cols <= set(rs):
                right.append((p, True))
            else:
                stuck.append((p, crossed))
        out = Join(_pushdown(node.left, left, trace),
                   _pushdown(node.right, right, trace),
                   node.left_on, node.right_on)
        return _wrap(out, stuck)
    if isinstance(node, Aggregate):
        out = Aggregate(_pushdown(node.child, [], trace), node.keys,
                        node.aggs)
        return _wrap(out, preds)
    if isinstance(node, Udf):
        out = dataclasses.replace(node,
                                  child=_pushdown(node.child, [], trace))
        return _wrap(out, preds)
    raise TypeError(f"not a logical node: {node!r}")


# ---------------------------------------------------------------------------
# Pass 2: projection pruning
# ---------------------------------------------------------------------------

def _prune(node, required: Optional[set], trace: list[str]):
    """Narrow scans (and intermediate projections) to the columns the
    plan above actually references. ``required=None`` means "everything"
    (unknown consumer, e.g. below a UDF)."""
    if isinstance(node, Scan):
        if required is None:
            if node.columns is None:
                raise LogicalError(
                    f"scan({node.table!r}) needs explicit columns: its "
                    "consumer's column needs cannot be inferred (declare "
                    "columns on the scan or output_columns on the UDF)")
            return node
        if node.columns is None:
            cols = sorted(required)
        else:
            cols = [c for c in node.columns if c in required]
        if node.columns is None or len(cols) < len(node.columns):
            trace.append(f"projection_pruning: scan({node.table}) "
                         f"columns -> {cols}")
        return Scan(node.table, cols)
    if isinstance(node, Filter):
        need = None if required is None else \
            required | logical.pred_columns(node.predicate)
        return Filter(_prune(node.child, need, trace), node.predicate)
    if isinstance(node, Project):
        cols = node.columns
        if required is not None:
            kept = [c for c in cols
                    if (c if isinstance(c, str) else c[0]) in required]
            if len(kept) < len(cols):
                trace.append(
                    f"projection_pruning: project narrowed to "
                    f"{[(c if isinstance(c, str) else c[0]) for c in kept]}")
            cols = kept
        return Project(_prune(node.child, logical.project_inputs(cols),
                              trace), cols)
    if isinstance(node, Join):
        ls, rs = logical.schema(node.left), logical.schema(node.right)
        if required is None or ls is None or rs is None:
            lreq = rreq = None
        else:
            lreq = (required & set(ls)) | {node.left_on}
            rreq = (required & set(rs)) | {node.right_on}
        return Join(_prune(node.left, lreq, trace),
                    _prune(node.right, rreq, trace),
                    node.left_on, node.right_on)
    if isinstance(node, Aggregate):
        need = set(node.keys) | {a.column for a in node.aggs}
        return Aggregate(_prune(node.child, need, trace), node.keys,
                         node.aggs)
    if isinstance(node, Udf):
        # The UDF's input needs are opaque: keep the child's declared
        # columns as-is.
        return dataclasses.replace(node,
                                   child=_prune(node.child, None, trace))
    raise TypeError(f"not a logical node: {node!r}")


# ---------------------------------------------------------------------------
# Lowering to physical pipelines
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Pipe:
    """A physical pipeline under construction."""
    input: object
    base_name: str
    input2: Optional[ShuffleInput] = None
    ops: list = dataclasses.field(default_factory=list)
    schema: Optional[list[str]] = None
    est_bytes: Optional[float] = None
    has_join: bool = False
    has_agg: bool = False


class _Lowering:
    def __init__(self, query: LogicalQuery, stats: Optional[Stats],
                 backend: str, bench_path: Optional[str],
                 trace: list[str]):
        self.query = query
        self.stats = stats or Stats()
        self.backend = backend
        self.bench_path = bench_path
        self.trace = trace
        self.pipelines: list[Pipeline] = []
        self._names: dict[str, int] = {}

    # -- naming / closing ---------------------------------------------------
    def _unique(self, base: str) -> str:
        n = self._names.get(base, 0)
        self._names[base] = n + 1
        return base if n == 0 else f"{base}_{n + 1}"

    def _close(self, pipe: _Pipe, output) -> str:
        base = pipe.base_name
        if pipe.has_join:
            base = "join_agg" if pipe.has_agg else "join"
        name = self._unique(base)
        self.pipelines.append(Pipeline(
            name=name, input=pipe.input, ops=pipe.ops, output=output,
            input2=pipe.input2))
        return name

    # -- physical choices ---------------------------------------------------
    def _cpu_bw(self) -> float:
        return bench_profile.cpu_bytes_per_s(
            self.backend, FALLBACK_CPU_BYTES_PER_S[self.backend],
            path=self.bench_path)

    def _fanout(self, est_bytes: Optional[float], what: str,
                allow_hint: bool = True) -> int:
        if allow_hint and self.query.shuffle_partitions:
            n = self.query.shuffle_partitions
            self.trace.append(f"shuffle_fanout: {what} -> {n} partitions "
                              f"(explicit hint)")
            return n
        if est_bytes is None:
            n = DEFAULT_SHUFFLE_PARTITIONS
            self.trace.append(f"shuffle_fanout: {what} -> {n} partitions "
                              f"(no stats; default)")
            return n
        target = self._cpu_bw() * TARGET_PARTITION_SECONDS
        n = max(1, min(MAX_SHUFFLE_PARTITIONS,
                       math.ceil(est_bytes / target)))
        self.trace.append(
            f"shuffle_fanout: {what} -> {n} partitions "
            f"(~{est_bytes / MIB:.1f} MiB at "
            f"{self._cpu_bw() / MIB:.0f} MiB/s per {TARGET_PARTITION_SECONDS}s "
            f"partition)")
        return n

    # -- tree walk ----------------------------------------------------------
    def build(self, node) -> _Pipe:
        if isinstance(node, Scan):
            if node.columns is None:
                raise LogicalError(
                    f"scan({node.table!r}) reached lowering without "
                    "columns; declare them or reference them upstream")
            return _Pipe(input=TableInput(node.table, list(node.columns)),
                         base_name=f"scan_{node.table}",
                         schema=list(node.columns),
                         est_bytes=self.stats.bytes_for(node.table))
        if isinstance(node, Filter):
            pipe = self.build(node.child)
            pipe.ops.append({"op": "filter", "expr": node.predicate})
            if pipe.est_bytes is not None:
                pipe.est_bytes *= FILTER_SELECTIVITY
            return pipe
        if isinstance(node, Project):
            pipe = self.build(node.child)
            pipe.ops.append({"op": "project", "columns": node.columns})
            new_schema = [c if isinstance(c, str) else c[0]
                          for c in node.columns]
            if pipe.est_bytes is not None and pipe.schema:
                pipe.est_bytes *= len(new_schema) / max(1, len(pipe.schema))
            pipe.schema = new_schema
            return pipe
        if isinstance(node, Udf):
            pipe = self.build(node.child)
            op = {"op": "udf", "name": node.name, "kwargs": node.kwargs}
            if node.broadcast:
                op["broadcast"] = node.broadcast
            pipe.ops.append(op)
            pipe.schema = list(node.output_columns) \
                if node.output_columns else None
            return pipe
        if isinstance(node, Join):
            return self._build_join(node)
        if isinstance(node, Aggregate):
            return self._build_aggregate(node)
        raise TypeError(f"not a logical node: {node!r}")

    def _build_join(self, node: Join) -> _Pipe:
        left = self.build(node.left)
        right = self.build(node.right)
        # Build side: the smaller estimated input is held in memory;
        # ties (and missing stats) keep the right side as build, which
        # preserves the conventional fact-probes-dimension authoring
        # order. The physical join drops the BUILD key from its output,
        # so a swap flips which key column survives: downstream ops were
        # authored against the logical schema (left cols + right cols
        # minus right_on) and a reconciling projection restores it. That
        # projection needs both schemas, so a swap with differently
        # named keys is only taken when they are known.
        swap = (left.est_bytes is not None and right.est_bytes is not None
                and left.est_bytes < right.est_bytes)
        if swap and node.left_on != node.right_on \
                and (left.schema is None or right.schema is None):
            swap = False
        probe, build = (right, left) if swap else (left, right)
        probe_on, build_on = (node.right_on, node.left_on) if swap \
            else (node.left_on, node.right_on)
        self.trace.append(
            "join_build_side: build = "
            + ("left" if swap else "right")
            + f" ({_fmt_bytes(build.est_bytes)} vs probe "
            + f"{_fmt_bytes(probe.est_bytes)})")
        known = [e for e in (probe.est_bytes, build.est_bytes)
                 if e is not None]
        parts = self._fanout(max(known) if known else None,
                             f"join on {probe_on}")
        probe_name = self._close(probe, ShuffleOutput(probe_on, parts))
        build_name = self._close(build, ShuffleOutput(build_on, parts))
        ops = [{"op": "hash_join", "left_key": probe_on,
                "right_key": build_on}]
        # The logical contract, regardless of build side.
        out_schema = logical.join_output_schema(left.schema, right.schema,
                                                node.right_on)
        if swap and node.left_on != node.right_on:
            # Swapped physical output carries right_on instead of
            # left_on (equal values — it is an equi-join): rename it
            # back and restore the logical column order.
            ops.append({"op": "project", "columns": [
                [node.left_on, node.right_on] if c == node.left_on else c
                for c in out_schema]})
        pipe = _Pipe(input=ShuffleInput(probe_name),
                     input2=ShuffleInput(build_name),
                     base_name="join",
                     ops=ops,
                     schema=out_schema, est_bytes=probe.est_bytes,
                     has_join=True)
        return pipe

    def _build_aggregate(self, node: Aggregate) -> _Pipe:
        pipe = self.build(node.child)
        partial = [[a.name, a.fn, a.column] for a in node.aggs]
        pipe.ops.append({"op": "hash_agg", "keys": list(node.keys),
                         "aggs": partial})
        pipe.has_agg = True
        out_cols = list(node.keys) + [a.name for a in node.aggs]
        # Combine shuffle: partition by the first group key; a global
        # aggregate has one row per fragment, so any produced column
        # works at the computed (small) fan-out — no synthetic __zero__
        # column needed.
        combine_key = node.keys[0] if node.keys else node.aggs[0].name
        # Partial aggregation shrinks the data by roughly the group
        # cardinality; estimate the combine input as a fraction of the
        # pre-agg bytes so genuinely large grouped inputs (high-
        # cardinality keys at paper scale) still fan their combine out.
        est_out = AGG_EST_OUTPUT_BYTES if pipe.est_bytes is None \
            else pipe.est_bytes * AGG_OUTPUT_FRACTION
        if node.keys:
            # Combine shuffles are optimizer-owned: the fan-out follows
            # the partial-output estimate, NOT the row-shuffle hint — a
            # wide hinted combine would schedule mostly-empty final
            # fragments and multiply shuffle-read probes for nothing.
            parts = self._fanout(est_out,
                                 f"aggregate combine on {combine_key}",
                                 allow_hint=False)
        else:
            # A global aggregate MUST combine in one fragment (its
            # partition key is a partial value, not a grouping key) —
            # never let the cost model fan it out.
            parts = 1
            self.trace.append(f"shuffle_fanout: global-aggregate combine "
                              f"on {combine_key} -> 1 partition (forced)")
        name = self._close(pipe, ShuffleOutput(combine_key, parts))
        final = [[a.name, logical.FINAL_AGG_FN[a.fn], a.name]
                 for a in node.aggs]
        self.trace.append(
            f"agg_split: partial hash_agg in '{name}', final combine "
            "re-aggregates partials (count -> sum) downstream")
        return _Pipe(input=ShuffleInput(name), base_name="final_agg",
                     ops=[{"op": "hash_agg", "keys": list(node.keys),
                           "aggs": final}],
                     schema=out_cols, est_bytes=est_out, has_agg=True)


def _fmt_bytes(b: Optional[float]) -> str:
    return "unknown size" if b is None else f"~{b / MIB:.1f} MiB"


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def lower(query: LogicalQuery, stats: Optional[Stats] = None,
          backend: str = "numpy", bench_path: Optional[str] = None
          ) -> tuple[QueryPlan, PlanReport]:
    """Optimize and lower a logical query. Returns the physical plan plus
    the report of applied rules (see ``engine.explain``)."""
    trace: list[str] = []
    root = _pushdown(query.root, [], trace)
    root = _prune(root, None, trace)
    low = _Lowering(query, stats, backend, bench_path, trace)
    pipe = low.build(root)
    low._close(pipe, CollectOutput())
    plan = QueryPlan(query.name, low.pipelines)
    plan.validate()
    return plan, PlanReport(query.name, trace, root)


def plan(query: LogicalQuery, stats: Optional[Stats] = None,
         backend: str = "numpy",
         bench_path: Optional[str] = None) -> QueryPlan:
    """``lower`` without the report — the one-call path for query
    builders."""
    return lower(query, stats=stats, backend=backend,
                 bench_path=bench_path)[0]
