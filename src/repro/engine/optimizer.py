"""Optimizing planner: lowers the logical IR to physical pipelines.

Rule-based passes over ``engine.logical`` trees, in order:

1. **Predicate pushdown** — filter conjuncts move through projections
   (rewriting renamed columns) and join sides down into the scans, so
   workers drop rows before shuffling them (Lambada's first lesson: pay
   object-store I/O for as few bytes as possible).
2. **Projection pruning** — every scan's column list narrows to exactly
   the columns referenced above it; bare ``scan("t")`` column lists are
   inferred. UDF stages without a declared output schema keep their
   explicit scan columns.
3. **Aggregate split** — each ``hash_agg`` becomes a per-fragment partial
   aggregate in the producing pipeline plus a final re-aggregation after
   a combine shuffle; count partials re-aggregate as sums
   (``logical.FINAL_AGG_FN``). This retires the hand-rolled
   ``__zero__`` single-partition shuffle idiom: the combine shuffle
   partitions by the first group key (or the first aggregate output for
   global aggregates — any column works at fan-out 1).
4. **Partitioning properties & shuffle elision** — every pipeline under
   construction carries an output-partitioning property
   (``hash(key) % fanout``, i.e. fragment i holds exactly the rows with
   ``key % fanout == i``). The property is established by a
   ``ShuffleOutput`` (the consumer's fragments align with the radix
   partition), by a ``Scan`` whose table declares
   ``partitioned_by=(key, fanout)``, and it propagates through filters,
   projections (rename-aware) and joins (probe rows never move). Two
   elision rules consume it:

   * *combine elision* — an aggregate whose producing pipeline is
     already partitioned by one of its group keys (or lives in a single
     fragment) collapses the partial/final split into ONE fragment-local
     aggregation: group-key classes are fragment-disjoint, so no combine
     shuffle (write + read + final fragments) is needed at all.
   * *co-partition join elision* — a join side already partitioned by
     its join key at fan-out n continues in place as the probe (no row
     shuffle); the other side shuffles at the SAME fan-out (forced, hint
     ignored), or, when it is itself an already-co-partitioned
     pass-through, its producer's partition slices are read directly as
     the build input with no rewrite.

   Elided pipelines record the property they relied on in
   ``Pipeline.partitioning`` (checked by ``QueryPlan.validate()`` and
   re-verified against actual key values by the worker). The rule always
   emits a trace line — ``shuffle_elision: ... elided`` or
   ``shuffle_elision: ... kept (reason)`` — so ``explain`` shows it
   firing even when it changes nothing.

5. **Physical choices** — the join build side is the smaller estimated
   input (probe keeps its storage order and the build side is the one
   held in memory); shuffle fan-out is chosen so one partition is about
   ``TARGET_PARTITION_SECONDS`` of work at the measured
   ``core.bench_profile`` throughput (falling back to hand-set
   constants), clamped to [1, MAX_SHUFFLE_PARTITIONS]. An explicit
   ``LogicalQuery.shuffle_partitions`` hint pins the fan-out of ROW
   shuffles (join co-partitioning); aggregate-combine shuffles are
   optimizer-owned — the partial agg already shrank the data, so the
   combine follows its own (small) estimate, and a global aggregate's
   combine is always 1 partition (its partition key is a partial value,
   not a grouping key). Size estimates are column-width aware when
   ``Stats`` carries per-column dtype widths (``Stats.from_store`` peeks
   them from object headers): scans count only the bytes of the columns
   they read and projections scale by dtype width, so narrow-int tables
   stop being over-estimated in build-side and fan-out choices.

The emitted ``plans.QueryPlan`` uses only today's physical vocabulary, so
the numpy and jit backends (including the fused join->ops->partition
trace) run lowered plans unchanged. ``lower`` returns the plan plus a
``PlanReport`` recording every applied rule (rendered by
``engine.explain``); ``lower(..., shuffle_elision=False)`` disables the
elision rules (parity tests and the ``shuffle_elision`` benchmark lower
both variants from one logical query).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core import bench_profile, breakeven
from repro.engine import logical
from repro.engine.logical import (Aggregate, Filter, Join, LogicalError,
                                  LogicalQuery, Project, Scan, Udf)
from repro.engine.plans import (CollectOutput, Pipeline, QueryPlan,
                                ShuffleInput, ShuffleOutput, TableInput)

MIB = 1024.0 ** 2

# Physical-choice knobs. Fallback throughputs mirror the coordinator's
# hand-set constants; when BENCH_engine.json is present the measured
# numbers win (core.bench_profile).
FALLBACK_CPU_BYTES_PER_S = {"numpy": 600e6, "jit": 1.5e9}
TARGET_PARTITION_SECONDS = 0.25
MAX_SHUFFLE_PARTITIONS = 64
DEFAULT_SHUFFLE_PARTITIONS = 8      # no stats, no hint
FILTER_SELECTIVITY = 0.2            # default per-filter row survival
AGG_OUTPUT_FRACTION = 0.05          # partial-agg output / input estimate
AGG_EST_OUTPUT_BYTES = 1.0 * MIB    # fallback when the input is unsized
# Join elision forces the build side to the probe's existing fan-out; if
# that leaves per-fragment build slices beyond this multiple of the
# target partition size, the forced co-partitioning is too coarse and
# the size-based (unelided) plan wins.
ELIDE_BUILD_SLICE_FACTOR = 4.0


@dataclasses.dataclass
class Stats:
    """Planner-visible table statistics: bytes on the object store, plus
    (optional) per-column dtype widths so size estimates scale with the
    bytes a plan actually touches instead of a flat column count."""
    table_bytes: dict[str, float] = dataclasses.field(default_factory=dict)
    column_widths: dict[str, dict[str, int]] = \
        dataclasses.field(default_factory=dict)

    @staticmethod
    def from_store(store, table_keys: dict[str, list[str]]) -> "Stats":
        out: dict[str, float] = {}
        widths: dict[str, dict[str, int]] = {}
        for table, keys in table_keys.items():
            try:
                out[table] = float(sum(store.size(k) for k in keys))
            except KeyError:
                continue
            if keys:
                try:
                    # Header-only peek at the first partition object.
                    from repro.engine import columnar
                    widths[table] = columnar.schema_widths(
                        store.get(keys[0]))
                except Exception:
                    pass   # width-unaware estimates still work
        return Stats(out, widths)

    def bytes_for(self, table: str) -> Optional[float]:
        return self.table_bytes.get(table)

    def widths_for(self, table: str) -> Optional[dict[str, int]]:
        return self.column_widths.get(table) or None


# Width assumed for derived/unknown columns (f64) in width-aware
# estimates.
DEFAULT_COLUMN_WIDTH = 8


# Fraction of a worker's memory cap one fragment's input may target:
# the rest is headroom for the partition buffers / join build / output
# the fragment materializes on top of its input. Fan-out derived from
# memory pressure keeps fragments inside this window when it can; past
# MAX_SHUFFLE_PARTITIONS the worker's morsel streaming + spill absorb
# the remainder.
MEMORY_TARGET_FRACTION = 0.5


def memory_fanout(est_bytes: Optional[float],
                  memory_budget: Optional[float]) -> int:
    """Minimum fan-out for one fragment's input slice to fit inside
    ``MEMORY_TARGET_FRACTION`` of the per-worker memory cap."""
    if est_bytes is None or not memory_budget:
        return 1
    return max(1, math.ceil(est_bytes /
                            (memory_budget * MEMORY_TARGET_FRACTION)))


def derive_fanout(est_bytes: Optional[float], backend: str,
                  bench_path: Optional[str] = None,
                  memory_budget: Optional[float] = None) -> int:
    """Size-based shuffle fan-out: one partition is about
    ``TARGET_PARTITION_SECONDS`` of work at the measured backend
    throughput — AND, under a per-worker ``memory_budget`` (bytes), small
    enough that a fragment's input slice fits its memory window
    (``memory_fanout``) — clamped to [1, MAX_SHUFFLE_PARTITIONS].

    Module-level because two layers make the same decision: lowering
    (``_Lowering._fanout``, from estimates) and the adaptive executor
    (``engine.adaptive``, from bytes observed at a stage boundary, which
    re-derives with the same memory term).
    """
    if est_bytes is None:
        return DEFAULT_SHUFFLE_PARTITIONS
    bw = bench_profile.cpu_bytes_per_s(
        backend, FALLBACK_CPU_BYTES_PER_S[backend], path=bench_path)
    n = max(math.ceil(est_bytes / (bw * TARGET_PARTITION_SECONDS)),
            memory_fanout(est_bytes, memory_budget))
    return max(1, min(MAX_SHUFFLE_PARTITIONS, n))


@dataclasses.dataclass
class PlanReport:
    """What the optimizer did: the rewritten logical tree plus one line
    per applied rule, in application order."""
    name: str
    rules: list[str]
    logical_root: object


# ---------------------------------------------------------------------------
# Pass 1: predicate pushdown
# ---------------------------------------------------------------------------

def _conjuncts(pred: list) -> list[list]:
    return list(pred[1:]) if pred[0] == "and" else [pred]


def _combine(preds: list[list]) -> list:
    return preds[0] if len(preds) == 1 else ["and"] + preds


def _rename_pred(expr: list, m: dict) -> list:
    op = expr[0]
    if op in ("and", "or"):
        return [op] + [_rename_pred(e, m) for e in expr[1:]]
    if op == "ltcol":
        return [op, m[expr[1]], m[expr[2]]]
    return [op, m[expr[1]]] + list(expr[2:])


def _wrap(node, stuck: list[tuple[list, bool]]):
    if not stuck:
        return node
    return Filter(node, _combine([p for p, _ in stuck]))


def _pushdown(node, preds: list[tuple[list, bool]], trace: list[str]):
    """Place each (predicate, crossed-a-boundary) pair as deep as it can
    go; record a rule line whenever a crossed predicate lands on a scan."""
    if isinstance(node, Filter):
        mine = [(c, False) for c in _conjuncts(node.predicate)]
        return _pushdown(node.child, preds + mine, trace)
    if isinstance(node, Scan):
        if not preds:
            return node
        crossed = sum(1 for _, c in preds if c)
        if crossed:
            trace.append(f"predicate_pushdown: {crossed} conjunct(s) "
                         f"pushed into scan({node.table})")
        return Filter(node, _combine([p for p, _ in preds]))
    if isinstance(node, Project):
        bindings = {}
        for c in node.columns:
            if isinstance(c, str):
                bindings[c] = c
            elif isinstance(c[1], str):
                bindings[c[0]] = c[1]           # pure rename
        pushable, stuck = [], []
        for p, crossed in preds:
            cols = logical.pred_columns(p)
            if cols <= set(bindings):
                pushable.append((_rename_pred(p, bindings), True))
            else:
                stuck.append((p, crossed))
        out = Project(_pushdown(node.child, pushable, trace), node.columns)
        return _wrap(out, stuck)
    if isinstance(node, Join):
        ls, rs = logical.schema(node.left), logical.schema(node.right)
        left, right, stuck = [], [], []
        for p, crossed in preds:
            cols = logical.pred_columns(p)
            if ls is not None and cols <= set(ls):
                left.append((p, True))
            elif rs is not None and cols <= set(rs):
                right.append((p, True))
            else:
                stuck.append((p, crossed))
        out = Join(_pushdown(node.left, left, trace),
                   _pushdown(node.right, right, trace),
                   node.left_on, node.right_on)
        return _wrap(out, stuck)
    if isinstance(node, Aggregate):
        out = Aggregate(_pushdown(node.child, [], trace), node.keys,
                        node.aggs)
        return _wrap(out, preds)
    if isinstance(node, Udf):
        out = dataclasses.replace(node,
                                  child=_pushdown(node.child, [], trace))
        return _wrap(out, preds)
    raise TypeError(f"not a logical node: {node!r}")


# ---------------------------------------------------------------------------
# Pass 2: projection pruning
# ---------------------------------------------------------------------------

def _prune(node, required: Optional[set], trace: list[str]):
    """Narrow scans (and intermediate projections) to the columns the
    plan above actually references. ``required=None`` means "everything"
    (unknown consumer, e.g. below a UDF)."""
    if isinstance(node, Scan):
        if required is None:
            if node.columns is None:
                raise LogicalError(
                    f"scan({node.table!r}) needs explicit columns: its "
                    "consumer's column needs cannot be inferred (declare "
                    "columns on the scan or output_columns on the UDF)")
            return node
        if node.columns is None:
            cols = sorted(required)
        else:
            cols = [c for c in node.columns if c in required]
        if node.columns is None or len(cols) < len(node.columns):
            trace.append(f"projection_pruning: scan({node.table}) "
                         f"columns -> {cols}")
        return Scan(node.table, cols, partitioned_by=node.partitioned_by)
    if isinstance(node, Filter):
        need = None if required is None else \
            required | logical.pred_columns(node.predicate)
        return Filter(_prune(node.child, need, trace), node.predicate)
    if isinstance(node, Project):
        cols = node.columns
        if required is not None:
            kept = [c for c in cols
                    if (c if isinstance(c, str) else c[0]) in required]
            if len(kept) < len(cols):
                trace.append(
                    f"projection_pruning: project narrowed to "
                    f"{[(c if isinstance(c, str) else c[0]) for c in kept]}")
            cols = kept
        return Project(_prune(node.child, logical.project_inputs(cols),
                              trace), cols)
    if isinstance(node, Join):
        ls, rs = logical.schema(node.left), logical.schema(node.right)
        if required is None or ls is None or rs is None:
            lreq = rreq = None
        else:
            lreq = (required & set(ls)) | {node.left_on}
            rreq = (required & set(rs)) | {node.right_on}
        return Join(_prune(node.left, lreq, trace),
                    _prune(node.right, rreq, trace),
                    node.left_on, node.right_on)
    if isinstance(node, Aggregate):
        need = set(node.keys) | {a.column for a in node.aggs}
        return Aggregate(_prune(node.child, need, trace), node.keys,
                         node.aggs)
    if isinstance(node, Udf):
        # The UDF's input needs are opaque: keep the child's declared
        # columns as-is.
        return dataclasses.replace(node,
                                   child=_prune(node.child, None, trace))
    raise TypeError(f"not a logical node: {node!r}")


# ---------------------------------------------------------------------------
# Lowering to physical pipelines
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Pipe:
    """A physical pipeline under construction."""
    input: object
    base_name: str
    input2: Optional[ShuffleInput] = None
    ops: list = dataclasses.field(default_factory=list)
    schema: Optional[list[str]] = None
    est_bytes: Optional[float] = None
    has_join: bool = False
    has_agg: bool = False
    # Output-partitioning property: rows of fragment i satisfy
    # ``part[0] % part[1] == i`` under the CURRENT schema's column names
    # (renames tracked). ``input_part`` is the same property named as the
    # upstream producer emits it (``ShuffleOutput.partition_by`` /
    # declared table key) — recorded as ``Pipeline.partitioning`` when an
    # elision relied on it (``relied``).
    part: Optional[tuple[str, int]] = None
    input_part: Optional[tuple[str, int]] = None
    # Declared layout of a TableInput build side read directly as
    # partition slices (recorded as ``Pipeline.partitioning2``).
    input_part2: Optional[tuple[str, int]] = None
    relied: bool = False
    # Why ``part`` is None when a property existed upstream (trace only).
    part_note: Optional[str] = None
    # Per-column dtype widths (bytes/value) under the current schema,
    # None when unknown; drives width-aware size estimates.
    col_widths: Optional[dict[str, int]] = None
    # Estimated producing-fragment count (shuffle WRITERS): known exactly
    # for pipelines fed by a shuffle or a declared-partitioned table,
    # None for plain scans (the coordinator derives parallelism from the
    # object count, invisible here). Exchange-tier placement needs it
    # because request count scales with writers x partitions.
    writers_est: Optional[int] = None

    def width_sum(self, cols) -> Optional[float]:
        if self.col_widths is None:
            return None
        return float(sum(self.col_widths.get(c, DEFAULT_COLUMN_WIDTH)
                         for c in cols))


class _Lowering:
    def __init__(self, query: LogicalQuery, stats: Optional[Stats],
                 backend: str, bench_path: Optional[str],
                 trace: list[str], elide: bool = True,
                 exchange_tiers: str = "auto",
                 memory_budget: Optional[float] = None):
        self.query = query
        self.stats = stats or Stats()
        self.backend = backend
        self.bench_path = bench_path
        self.trace = trace
        self.elide = elide
        self.exchange_tiers = exchange_tiers
        self.memory_budget = memory_budget
        self.pipelines: list[Pipeline] = []
        self._names: dict[str, int] = {}

    # -- naming / closing ---------------------------------------------------
    def _unique(self, base: str) -> str:
        n = self._names.get(base, 0)
        self._names[base] = n + 1
        return base if n == 0 else f"{base}_{n + 1}"

    def _close(self, pipe: _Pipe, output) -> str:
        base = pipe.base_name
        if pipe.has_join:
            base = "join_agg" if pipe.has_agg else "join"
        name = self._unique(base)
        partitioning = None
        fragments = None
        if pipe.relied and pipe.input_part is not None:
            partitioning = {"key": pipe.input_part[0],
                            "fanout": pipe.input_part[1]}
            if isinstance(pipe.input, TableInput):
                # Declared table partitioning: stored partition i must BE
                # fragment i, so the parallelism is pinned to the fan-out.
                fragments = pipe.input_part[1]
        self.pipelines.append(Pipeline(
            name=name, input=pipe.input, ops=pipe.ops, output=output,
            input2=pipe.input2, fragments=fragments,
            partitioning=partitioning,
            partitioning2=None if pipe.input_part2 is None else
            {"key": pipe.input_part2[0], "fanout": pipe.input_part2[1]}))
        return name

    # -- physical choices ---------------------------------------------------
    def _cpu_bw(self) -> float:
        return bench_profile.cpu_bytes_per_s(
            self.backend, FALLBACK_CPU_BYTES_PER_S[self.backend],
            path=self.bench_path)

    def _fanout(self, est_bytes: Optional[float], what: str,
                allow_hint: bool = True) -> int:
        if allow_hint and self.query.shuffle_partitions:
            n = self.query.shuffle_partitions
            self.trace.append(f"shuffle_fanout: {what} -> {n} partitions "
                              f"(explicit hint)")
            return n
        if est_bytes is None:
            n = DEFAULT_SHUFFLE_PARTITIONS
            self.trace.append(f"shuffle_fanout: {what} -> {n} partitions "
                              f"(no stats; default)")
            return n
        n = derive_fanout(est_bytes, self.backend,
                          bench_path=self.bench_path,
                          memory_budget=self.memory_budget)
        n_mem = memory_fanout(est_bytes, self.memory_budget)
        n_tput = derive_fanout(est_bytes, self.backend,
                               bench_path=self.bench_path)
        self.trace.append(
            f"shuffle_fanout: {what} -> {n} partitions "
            f"(~{est_bytes / MIB:.1f} MiB at "
            f"{self._cpu_bw() / MIB:.0f} MiB/s per {TARGET_PARTITION_SECONDS}s "
            f"partition)")
        if self.memory_budget and n_mem > n_tput:
            self.trace.append(
                f"shuffle_fanout: {what} memory pressure -> >= {n_mem} "
                f"partitions (~{est_bytes / MIB:.1f} MiB vs "
                f"{self.memory_budget * MEMORY_TARGET_FRACTION / MIB:.0f} MiB "
                f"per-fragment window of {self.memory_budget / MIB:.0f} MiB "
                f"cap)")
        return n

    def _shuffle_out(self, key: str, partitions: int,
                     est_bytes: Optional[float],
                     writers_est: Optional[int],
                     what: str) -> ShuffleOutput:
        """Build a ``ShuffleOutput`` with its exchange tier chosen by the
        break-even model (``core.breakeven.place_exchange``): estimated
        shuffle bytes spread over writers x partitions round trips against
        the measured tier throughputs from the ``tiered_exchange`` bench
        section. Small hot shuffles (combines) land on the KV tier, bulk
        row shuffles stay on the object store; no hand rules. A ``None``
        break-even or a missing size estimate falls back to the object
        store with a trace note — never a crash."""
        if self.exchange_tiers in ("object", "kv"):
            self.trace.append(f"exchange_tier: {what} -> "
                              f"{self.exchange_tiers} (forced)")
            return ShuffleOutput(key, partitions, tier=self.exchange_tiers)
        writers = writers_est
        if writers is None:
            if est_bytes is not None:
                # Mirror the coordinator's parallelism heuristic: one
                # fragment per target-partition of input.
                target = self._cpu_bw() * TARGET_PARTITION_SECONDS
                writers = max(1, min(MAX_SHUFFLE_PARTITIONS,
                                     math.ceil(est_bytes / target)))
            else:
                writers = DEFAULT_SHUFFLE_PARTITIONS
        placed = breakeven.place_exchange_from_bench(
            est_bytes, writers, partitions, bench_path=self.bench_path)
        if placed.access_bytes is None or placed.object_usd is None:
            self.trace.append(
                f"exchange_tier: {what} -> {placed.tier} ({placed.note})")
        else:
            self.trace.append(
                f"exchange_tier: {what} -> {placed.tier} ({placed.note}; "
                f"{placed.n_objects} round trips, modeled object "
                f"${placed.object_usd:.6f}/{placed.object_s * 1e3:.1f}ms "
                f"vs kv ${placed.kv_usd:.6f}/{placed.kv_s * 1e3:.1f}ms)")
        return ShuffleOutput(key, partitions, tier=placed.tier)

    # -- tree walk ----------------------------------------------------------
    def build(self, node) -> _Pipe:
        if isinstance(node, Scan):
            if node.columns is None:
                raise LogicalError(
                    f"scan({node.table!r}) reached lowering without "
                    "columns; declare them or reference them upstream")
            cols = list(node.columns)
            est = self.stats.bytes_for(node.table)
            widths = self.stats.widths_for(node.table)
            col_widths = None
            if widths is not None:
                col_widths = {c: widths.get(c, DEFAULT_COLUMN_WIDTH)
                              for c in cols}
                total = float(sum(widths.values()))
                if est is not None and total > 0:
                    # Projection pushdown reads only the scanned columns'
                    # bytes; scale by their dtype widths, not count.
                    est *= sum(col_widths.values()) / total
            part = None
            if node.partitioned_by is not None \
                    and node.partitioned_by[0] in cols:
                part = (node.partitioned_by[0], node.partitioned_by[1])
                self.trace.append(
                    f"partition_property: scan({node.table}) declared "
                    f"partitioned hash({part[0]}) % {part[1]}")
            return _Pipe(input=TableInput(node.table, cols),
                         base_name=f"scan_{node.table}",
                         schema=cols, est_bytes=est,
                         part=part, input_part=part,
                         col_widths=col_widths,
                         writers_est=None if part is None else part[1])
        if isinstance(node, Filter):
            pipe = self.build(node.child)
            pipe.ops.append({"op": "filter", "expr": node.predicate})
            if pipe.est_bytes is not None:
                pipe.est_bytes *= FILTER_SELECTIVITY
            return pipe
        if isinstance(node, Project):
            pipe = self.build(node.child)
            pipe.ops.append({"op": "project", "columns": node.columns})
            new_schema = [c if isinstance(c, str) else c[0]
                          for c in node.columns]
            new_widths = None
            if pipe.col_widths is not None:
                new_widths = {}
                for c in node.columns:
                    if isinstance(c, str):
                        new_widths[c] = pipe.col_widths.get(
                            c, DEFAULT_COLUMN_WIDTH)
                    elif isinstance(c[1], str):      # pure rename
                        new_widths[c[0]] = pipe.col_widths.get(
                            c[1], DEFAULT_COLUMN_WIDTH)
                    else:                            # derived: f64
                        new_widths[c[0]] = DEFAULT_COLUMN_WIDTH
            if pipe.est_bytes is not None:
                old_w = pipe.width_sum(pipe.schema) if pipe.schema else None
                new_w = None if new_widths is None else \
                    float(sum(new_widths.values()))
                if old_w and new_w is not None:
                    pipe.est_bytes *= new_w / old_w
                elif pipe.schema:
                    pipe.est_bytes *= len(new_schema) / max(1,
                                                            len(pipe.schema))
            pipe.schema = new_schema
            pipe.col_widths = new_widths
            new_part = _project_part(pipe.part, node.columns)
            if pipe.part is not None and new_part is None:
                pipe.part_note = (f"was {_fmt_part(pipe.part)} until a "
                                  f"projection dropped {pipe.part[0]}")
            pipe.part = new_part
            return pipe
        if isinstance(node, Udf):
            pipe = self.build(node.child)
            op = {"op": "udf", "name": node.name, "kwargs": node.kwargs}
            if node.broadcast:
                op["broadcast"] = node.broadcast
            pipe.ops.append(op)
            pipe.schema = list(node.output_columns) \
                if node.output_columns else None
            if pipe.part is not None:   # UDFs may rewrite rows arbitrarily
                pipe.part_note = (f"was {_fmt_part(pipe.part)} until udf "
                                  f"{node.name}")
            pipe.part = None
            pipe.col_widths = None
            return pipe
        if isinstance(node, Join):
            return self._build_join(node)
        if isinstance(node, Aggregate):
            return self._build_aggregate(node)
        raise TypeError(f"not a logical node: {node!r}")

    def _build_join(self, node: Join) -> _Pipe:
        left = self.build(node.left)
        right = self.build(node.right)
        elided = self._try_elide_join(node, left, right)
        if elided is not None:
            return elided
        # Build side: the smaller estimated input is held in memory;
        # ties (and missing stats) keep the right side as build, which
        # preserves the conventional fact-probes-dimension authoring
        # order. The physical join drops the BUILD key from its output,
        # so a swap flips which key column survives: downstream ops were
        # authored against the logical schema (left cols + right cols
        # minus right_on) and a reconciling projection restores it. That
        # projection needs both schemas, so a swap with differently
        # named keys is only taken when they are known.
        swap = (left.est_bytes is not None and right.est_bytes is not None
                and left.est_bytes < right.est_bytes)
        if swap and node.left_on != node.right_on \
                and (left.schema is None or right.schema is None):
            swap = False
        probe, build = (right, left) if swap else (left, right)
        probe_on, build_on = (node.right_on, node.left_on) if swap \
            else (node.left_on, node.right_on)
        self.trace.append(
            "join_build_side: build = "
            + ("left" if swap else "right")
            + f" ({_fmt_bytes(build.est_bytes)} vs probe "
            + f"{_fmt_bytes(probe.est_bytes)})")
        known = [e for e in (probe.est_bytes, build.est_bytes)
                 if e is not None]
        parts = self._fanout(max(known) if known else None,
                             f"join on {probe_on}")
        probe_name = self._close(probe, self._shuffle_out(
            probe_on, parts, probe.est_bytes, probe.writers_est,
            f"row shuffle on {probe_on}"))
        build_name = self._close(build, self._shuffle_out(
            build_on, parts, build.est_bytes, build.writers_est,
            f"build shuffle on {build_on}"))
        ops = [{"op": "hash_join", "left_key": probe_on,
                "right_key": build_on}]
        # The logical contract, regardless of build side.
        out_schema = logical.join_output_schema(left.schema, right.schema,
                                                node.right_on)
        if swap and node.left_on != node.right_on:
            # Swapped physical output carries right_on instead of
            # left_on (equal values — it is an equi-join): rename it
            # back and restore the logical column order.
            ops.append({"op": "project", "columns": [
                [node.left_on, node.right_on] if c == node.left_on else c
                for c in out_schema]})
        self.trace.append(
            f"partition_property: join inputs co-partitioned "
            f"hash({probe_on}) % {parts} ('{probe_name}'/'{build_name}')")
        pipe = _Pipe(input=ShuffleInput(probe_name),
                     input2=ShuffleInput(build_name),
                     base_name="join",
                     ops=ops,
                     schema=out_schema, est_bytes=probe.est_bytes,
                     has_join=True,
                     # The join output inherits the co-partitioning: probe
                     # rows never leave their fragment and the build key's
                     # values equal the probe key's.
                     part=(node.left_on, parts),
                     input_part=(probe_on, parts),
                     col_widths=_merge_widths(left, right, node.right_on),
                     writers_est=parts)
        return pipe

    def _try_elide_join(self, node: Join, left: _Pipe,
                        right: _Pipe) -> Optional[_Pipe]:
        """Co-partition join elision: a side already partitioned by its
        join key continues in place as the probe — its row shuffle
        disappears. The other side shuffles at the SAME fan-out
        (co-partitioning is a correctness requirement, so the row-shuffle
        hint is ignored), or, when it is itself an already-aligned
        pass-through, its producer's partition slices are read directly
        as the build input with no rewrite. Emits a kept-line when the
        rule fires but cannot elide, so explain always shows it."""
        if not self.elide:
            return None
        lprop = left.part if left.part is not None \
            and left.part[0] == node.left_on else None
        rprop = right.part if right.part is not None \
            and right.part[0] == node.right_on else None
        if lprop is None and rprop is None:
            self.trace.append(
                f"shuffle_elision: join on {node.left_on} kept (neither "
                f"input is partitioned by its join key: left "
                f"{_fmt_part(left.part)}, right {_fmt_part(right.part)})")
            return None
        candidates = []
        if lprop is not None:
            candidates.append((left, right, node.left_on, node.right_on,
                               False, lprop))
        if rprop is not None:
            candidates.append((right, left, node.right_on, node.left_on,
                               True, rprop))
        skip_reason = None
        for probe, build, probe_on, build_on, swapped, prop in candidates:
            if probe.input2 is not None:
                continue   # pipeline already carries a build side
            if swapped and node.left_on != node.right_on and (
                    left.schema is None or right.schema is None):
                continue   # cannot emit the key-restoring rename
            n = prop[1]
            if build.est_bytes is not None:
                # The build is forced to the probe's fan-out and each
                # fragment holds one build slice in memory: refuse an
                # elision whose forced co-partitioning leaves slices far
                # beyond the target partition size — the unelided plan's
                # size-based build choice and fan-out win there.
                slice_budget = self._cpu_bw() * TARGET_PARTITION_SECONDS \
                    * ELIDE_BUILD_SLICE_FACTOR
                if build.est_bytes / max(1, n) > slice_budget:
                    skip_reason = (
                        f"forced fan-out {n} leaves "
                        f"~{build.est_bytes / max(1, n) / MIB:.0f} MiB "
                        f"build slices per fragment (budget "
                        f"~{slice_budget / MIB:.0f} MiB); size-based "
                        f"plan wins")
                    continue
            build_part2 = None
            build_aligned = build.part is not None \
                and build.part[0] == build_on and build.part[1] == n \
                and not build.ops and build.input2 is None
            if build_aligned and isinstance(build.input, ShuffleInput):
                # Already-aligned pass-through: no build-side rewrite —
                # the join reads its producer's partition slices directly.
                build_input = build.input
                self.trace.append(
                    f"shuffle_elision: both join sides already "
                    f"co-partitioned hash({probe_on}) % {n}; probe "
                    f"continues in place, build reads "
                    f"'{build.input.from_pipeline}' partition slices "
                    f"directly (both row shuffles elided)")
            elif build_aligned and isinstance(build.input, TableInput):
                # Declared hash-partitioned base table: fragment i reads
                # stored partition i as its build slice — no shuffle, no
                # rewrite (the worker re-verifies the declared layout).
                build_input = build.input
                build_part2 = build.input_part
                self.trace.append(
                    f"shuffle_elision: build side reads table "
                    f"'{build.input.table}' stored partition slices "
                    f"directly (declared hash({build_on}) % {n} layout; "
                    f"both row shuffles elided)")
            else:
                build_name = self._close(build, self._shuffle_out(
                    build_on, n, build.est_bytes, build.writers_est,
                    f"build shuffle on {build_on}"))
                build_input = ShuffleInput(build_name)
                self.trace.append(
                    f"shuffle_elision: probe-side row shuffle on "
                    f"{probe_on} elided (input already partitioned "
                    f"hash({probe_on}) % {n}); build '{build_name}' "
                    f"shuffles at the same fan-out (forced)")
            probe.ops.append({"op": "hash_join", "left_key": probe_on,
                              "right_key": build_on})
            out_schema = logical.join_output_schema(
                left.schema, right.schema, node.right_on)
            if swapped and node.left_on != node.right_on:
                # The continued (physical-right) probe keeps right_on;
                # rename it back to the logical left key.
                probe.ops.append({"op": "project", "columns": [
                    [node.left_on, node.right_on] if c == node.left_on
                    else c for c in out_schema]})
            probe.input2 = build_input
            probe.input_part2 = build_part2
            probe.has_join = True
            probe.schema = out_schema
            probe.col_widths = _merge_widths(left, right, node.right_on)
            probe.part = (node.left_on, n)
            probe.relied = True
            probe.writers_est = n
            return probe
        self.trace.append(
            f"shuffle_elision: join on {node.left_on} kept ("
            + (skip_reason or
               "the pre-partitioned side cannot continue in place: it "
               "already joins, or the key rename needs unknown schemas")
            + ")")
        return None

    def _build_aggregate(self, node: Aggregate) -> _Pipe:
        pipe = self.build(node.child)
        elided = self._try_elide_combine(node, pipe)
        if elided is not None:
            return elided
        partial = [[a.name, a.fn, a.column] for a in node.aggs]
        pipe.ops.append({"op": "hash_agg", "keys": list(node.keys),
                         "aggs": partial})
        pipe.has_agg = True
        out_cols = list(node.keys) + [a.name for a in node.aggs]
        # Combine shuffle: partition by the first group key; a global
        # aggregate has one row per fragment, so any produced column
        # works at the computed (small) fan-out — no synthetic __zero__
        # column needed.
        combine_key = node.keys[0] if node.keys else node.aggs[0].name
        # Partial aggregation shrinks the data by roughly the group
        # cardinality; estimate the combine input as a fraction of the
        # pre-agg bytes so genuinely large grouped inputs (high-
        # cardinality keys at paper scale) still fan their combine out.
        est_out = AGG_EST_OUTPUT_BYTES if pipe.est_bytes is None \
            else pipe.est_bytes * AGG_OUTPUT_FRACTION
        if node.keys:
            # Combine shuffles are optimizer-owned: the fan-out follows
            # the partial-output estimate, NOT the row-shuffle hint — a
            # wide hinted combine would schedule mostly-empty final
            # fragments and multiply shuffle-read probes for nothing.
            parts = self._fanout(est_out,
                                 f"aggregate combine on {combine_key}",
                                 allow_hint=False)
        else:
            # A global aggregate MUST combine in one fragment (its
            # partition key is a partial value, not a grouping key) —
            # never let the cost model fan it out.
            parts = 1
            self.trace.append(f"shuffle_fanout: global-aggregate combine "
                              f"on {combine_key} -> 1 partition (forced)")
        name = self._close(pipe, self._shuffle_out(
            combine_key, parts, est_out, pipe.writers_est,
            f"combine shuffle on {combine_key}"))
        final = [[a.name, logical.FINAL_AGG_FN[a.fn], a.name]
                 for a in node.aggs]
        self.trace.append(
            f"agg_split: partial hash_agg in '{name}', final combine "
            "re-aggregates partials (count -> sum) downstream")
        return _Pipe(input=ShuffleInput(name), base_name="final_agg",
                     ops=[{"op": "hash_agg", "keys": list(node.keys),
                           "aggs": final}],
                     schema=out_cols, est_bytes=est_out, has_agg=True,
                     # The combine shuffle partitions by a group key, so
                     # the final aggregate's output is itself partitioned
                     # by it — downstream joins/aggs on it can elide.
                     part=(combine_key, parts),
                     input_part=(combine_key, parts),
                     col_widths=_agg_widths(pipe, node),
                     writers_est=parts)

    def _try_elide_combine(self, node: Aggregate,
                           pipe: _Pipe) -> Optional[_Pipe]:
        """Combine-shuffle elision: when the producing pipeline is
        already partitioned by one of the aggregate's group keys (or
        lives in a single fragment), every group-key class is confined to
        one fragment — the partial and final aggregates collapse into ONE
        fragment-local aggregation and the combine shuffle (write + read
        + final fragments) disappears entirely. Emits a kept-line when
        the rule fires but cannot elide."""
        if not self.elide:
            return None
        prop = pipe.part
        keys = list(node.keys)
        elidable = prop is not None and (prop[1] == 1
                                         or (keys and prop[0] in keys))
        combine_key = keys[0] if keys else node.aggs[0].name
        if not elidable:
            if prop is not None:
                reason = (f"producer partitioned {_fmt_part(prop)}, "
                          "not by a group key")
            else:
                reason = pipe.part_note or \
                    "producer output is not hash-partitioned"
            self.trace.append(f"shuffle_elision: combine on {combine_key} "
                              f"kept ({reason})")
            return None
        aggs = [[a.name, a.fn, a.column] for a in node.aggs]
        pipe.ops.append({"op": "hash_agg", "keys": keys, "aggs": aggs})
        pipe.has_agg = True
        out_cols = keys + [a.name for a in node.aggs]
        pipe.schema = out_cols
        pipe.est_bytes = AGG_EST_OUTPUT_BYTES if pipe.est_bytes is None \
            else pipe.est_bytes * AGG_OUTPUT_FRACTION
        pipe.col_widths = _agg_widths(pipe, node)
        pipe.relied = True
        why = (f"group key {prop[0]}" if keys and prop is not None
               and prop[0] in keys else "single fragment")
        self.trace.append(
            f"shuffle_elision: aggregate combine on {combine_key} ELIDED "
            f"(producer already partitioned {_fmt_part(prop)}, {why}); "
            f"partial+final collapse into one fragment-local hash_agg — "
            f"no combine shuffle is written")
        # Groups keep the producer's partitioning (every group's key
        # class stays in its fragment); keyless single-fragment output is
        # trivially partitioned at fan-out 1.
        if keys and prop is not None and prop[0] in keys:
            pipe.part = prop
        elif prop is not None and prop[1] == 1:
            pipe.part = (out_cols[0], 1)
        else:
            pipe.part = None
        return pipe


def _fmt_bytes(b: Optional[float]) -> str:
    return "unknown size" if b is None else f"~{b / MIB:.1f} MiB"


def _agg_widths(pipe: "_Pipe", node: Aggregate) -> dict[str, int]:
    """Column widths of an aggregate output (aggregates emit f64)."""
    out = {}
    for k in node.keys:
        out[k] = DEFAULT_COLUMN_WIDTH if pipe.col_widths is None \
            else pipe.col_widths.get(k, DEFAULT_COLUMN_WIDTH)
    for a in node.aggs:
        out[a.name] = DEFAULT_COLUMN_WIDTH
    return out


def _merge_widths(left: _Pipe, right: _Pipe,
                  right_on: str) -> Optional[dict[str, int]]:
    """Column widths of a join output (build key dropped)."""
    if left.col_widths is None or right.col_widths is None:
        return None
    out = dict(left.col_widths)
    for c, w in right.col_widths.items():
        if c != right_on:
            out[c] = w
    return out


def _project_part(part: Optional[tuple[str, int]],
                  columns: list) -> Optional[tuple[str, int]]:
    """Partitioning property through a projection: survives when the
    partition column is kept (bare keeps win over pure renames)."""
    if part is None:
        return None
    key, n = part
    for c in columns:
        if isinstance(c, str) and c == key:
            return (key, n)
    for c in columns:
        if not isinstance(c, str) and isinstance(c[1], str) and c[1] == key:
            return (c[0], n)
    return None


def _fmt_part(part: Optional[tuple[str, int]]) -> str:
    return "not hash-partitioned" if part is None \
        else f"hash({part[0]}) % {part[1]}"


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def lower(query: LogicalQuery, stats: Optional[Stats] = None,
          backend: str = "numpy", bench_path: Optional[str] = None,
          shuffle_elision: bool = True,
          exchange_tiers: str = "auto",
          memory_budget: Optional[float] = None
          ) -> tuple[QueryPlan, PlanReport]:
    """Optimize and lower a logical query. Returns the physical plan plus
    the report of applied rules (see ``engine.explain``).
    ``shuffle_elision=False`` disables the partitioning-property elision
    rules — parity tests and benchmarks lower both variants from the same
    logical query. ``exchange_tiers`` selects shuffle placement:
    ``"auto"`` (default) picks per shuffle by break-even analysis,
    ``"object"``/``"kv"`` force every shuffle onto one tier (the
    ``tiered_exchange`` benchmark lowers all three variants from one
    logical query). ``memory_budget`` (bytes per worker) adds a memory
    term to shuffle fan-out derivation: a fragment's input slice should
    fit ``MEMORY_TARGET_FRACTION`` of the cap (see ``derive_fanout``)."""
    if exchange_tiers not in ("auto", "object", "kv"):
        raise ValueError(f"exchange_tiers must be 'auto', 'object' or "
                         f"'kv', got {exchange_tiers!r}")
    trace: list[str] = []
    root = _pushdown(query.root, [], trace)
    root = _prune(root, None, trace)
    low = _Lowering(query, stats, backend, bench_path, trace,
                    elide=shuffle_elision, exchange_tiers=exchange_tiers,
                    memory_budget=memory_budget)
    pipe = low.build(root)
    low._close(pipe, CollectOutput())
    plan = QueryPlan(query.name, low.pipelines)
    plan.validate()
    return plan, PlanReport(query.name, trace, root)


def plan(query: LogicalQuery, stats: Optional[Stats] = None,
         backend: str = "numpy", bench_path: Optional[str] = None,
         shuffle_elision: bool = True,
         exchange_tiers: str = "auto",
         memory_budget: Optional[float] = None) -> QueryPlan:
    """``lower`` without the report — the one-call path for query
    builders."""
    return lower(query, stats=stats, backend=backend,
                 bench_path=bench_path,
                 shuffle_elision=shuffle_elision,
                 exchange_tiers=exchange_tiers,
                 memory_budget=memory_budget)[0]
