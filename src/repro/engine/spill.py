"""Spill-to-disk substrate for out-of-core fragment execution.

When an operator's ``core.memory.OperatorGrant`` refuses a reservation,
buffered batches move to *spill files*: append-only local files holding
zero-copy ``columnar`` frames. Read-back memory-maps the file and hands
``columnar.deserialize_frame`` the mapped buffer, so spilled columns come
back as ``np.frombuffer`` views over OS-paged memory — only the columns
(and pages) an operator touches are ever resident, which is exactly the
column-sliced cheap-re-read property the frame format was built for.

Spill files are unlinked the moment they are opened (Linux keeps the
inode alive while the mapping exists), so worker crashes leak nothing.

``SPILL_STATS`` is the process-global spy the differential spill-parity
tests and the ``out_of_core`` bench section read: tests assert
``spill_bytes > 0`` and ``spill_rounds >= 2`` under a forcing budget, and
the bench records spilled volume next to rows/s.
"""
from __future__ import annotations

import mmap
import os
import tempfile
from typing import Iterable, Optional

from repro.core.memory import OperatorGrant
from repro.engine import columnar
from repro.engine.columnar import ColumnBatch

# Process-global observability: reset per run, read by tests/bench.
SPILL_STATS = {
    "spill_bytes": 0,        # frame bytes written to spill files
    "spill_chunks": 0,       # batches moved to disk
    "spill_rounds": 0,       # accumulator flush events (buffer -> disk)
    "spilled_builds": 0,     # hash-join build sides demoted to mmap frames
    "readback_bytes": 0,     # frame bytes mapped back for consumption
}


def reset_stats() -> None:
    for k in SPILL_STATS:
        SPILL_STATS[k] = 0


class SpillFile:
    """Append-only file of ``columnar`` frames with mmap read-back."""

    def __init__(self, prefix: str = "repro-spill-"):
        fd, path = tempfile.mkstemp(prefix=prefix, suffix=".frames")
        self._fd = fd
        os.unlink(path)              # anonymous: gone when fd/mmap die
        self._size = 0
        self._mm: Optional[mmap.mmap] = None

    def append(self, batch: ColumnBatch) -> tuple[int, int]:
        """Serialize ``batch`` as one frame at the tail; returns
        ``(offset, length)`` for later ``read``."""
        if self._mm is not None:
            raise RuntimeError("spill file is frozen for reading")
        data = columnar.serialize_frame(batch)
        offset = self._size
        os.pwrite(self._fd, data, offset)
        self._size += len(data)
        SPILL_STATS["spill_bytes"] += len(data)
        SPILL_STATS["spill_chunks"] += 1
        return offset, len(data)

    def _map(self) -> memoryview:
        if self._mm is None:
            self._mm = mmap.mmap(self._fd, self._size,
                                 access=mmap.ACCESS_READ)
        return memoryview(self._mm)

    def read(self, offset: int, length: int,
             columns: Optional[Iterable[str]] = None) -> ColumnBatch:
        """Zero-copy view of one spilled frame: columns are
        ``np.frombuffer`` over the mapping, paged in on access."""
        SPILL_STATS["readback_bytes"] += length
        return columnar.deserialize_frame(
            self._map()[offset:offset + length], columns)

    @property
    def nbytes(self) -> int:
        return self._size


def spill_build(batch: ColumnBatch) -> ColumnBatch:
    """Demote a hash-join build side to a spilled frame: the returned
    batch has the same columns/rows but every array is a zero-copy view
    over a memory-mapped frame file — file-backed, reclaimable pages
    instead of anonymous heap, which is what the join grant refused."""
    sf = SpillFile(prefix="repro-spill-build-")
    off, length = sf.append(batch)
    SPILL_STATS["spilled_builds"] += 1
    # The arrays keep the mmap (and file) alive via their .base chain.
    return sf.read(off, length)


class BatchAccumulator:
    """Order-preserving accumulator of morsel outputs under a grant.

    ``add`` reserves each batch's bytes; when the grant refuses, every
    buffered batch (and the incoming one) moves to the spill file — one
    *spill round* — and the reservations are released. ``finalize``
    concatenates all chunks in arrival order, mixing live and mapped
    arrays, reserving the output size (``force=True``: a barrier
    consumer needs the whole thing)."""

    def __init__(self, grant: OperatorGrant):
        self.grant = grant
        # Entries in arrival order: ("mem", batch) | ("disk", off, len).
        self._entries: list[tuple] = []
        self._file: Optional[SpillFile] = None
        self._mem_bytes = 0
        self.rows = 0

    def _spill_round(self) -> None:
        if self._file is None:
            self._file = SpillFile()
        for i, entry in enumerate(self._entries):
            if entry[0] == "mem":
                off, length = self._file.append(entry[1])
                self._entries[i] = ("disk", off, length)
        if self._mem_bytes:
            self.grant.release(self._mem_bytes)
            self._mem_bytes = 0
        SPILL_STATS["spill_rounds"] += 1

    def add(self, batch: ColumnBatch) -> None:
        if batch.num_rows == 0:
            return
        self.rows += batch.num_rows
        n = batch.nbytes()
        if self.grant.try_reserve(n):
            self._entries.append(("mem", batch))
            self._mem_bytes += n
            return
        self._spill_round()
        if self.grant.try_reserve(n):    # freed headroom fits the morsel
            self._entries.append(("mem", batch))
            self._mem_bytes += n
        else:                            # morsel alone exceeds the grant
            off, length = self._file.append(batch)
            self._entries.append(("disk", off, length))

    def _chunks(self) -> list[ColumnBatch]:
        out = []
        for entry in self._entries:
            if entry[0] == "mem":
                out.append(entry[1])
            else:
                out.append(self._file.read(entry[1], entry[2]))
        return out

    def finalize(self) -> ColumnBatch:
        chunks = self._chunks()
        had_disk = any(e[0] == "disk" for e in self._entries)
        self._entries = []
        batch = ColumnBatch.concat(chunks)
        if len(chunks) > 1 or had_disk:
            # Charge the materialized concat (force: a barrier consumer
            # needs it whole); buffered chunk reservations are released —
            # their arrays die with the entry list.
            if self._mem_bytes:
                self.grant.release(self._mem_bytes)
                self._mem_bytes = 0
            self.grant.reserve(batch.nbytes(), force=True)
        return batch


class PartitionAccumulator:
    """Per-partition chunked emission buffer for spill-aware shuffles.

    Each morsel's partition slices are appended under their partition id;
    over-grant buffers spill whole (one round covers every partition's
    buffered chunks — radix spill is all-or-nothing per round, keeping
    the round count meaningful). ``take(p)`` concatenates partition
    ``p``'s chunks in arrival order, so the shuffle object is
    bit-identical to the single-shot partitioner's output."""

    def __init__(self, partitions: int, grant: OperatorGrant):
        self.partitions = partitions
        self.grant = grant
        self._entries: list[list[tuple]] = [[] for _ in range(partitions)]
        self._file: Optional[SpillFile] = None
        self._mem_bytes = 0

    def _spill_round(self) -> None:
        if self._file is None:
            self._file = SpillFile()
        for plist in self._entries:
            for i, entry in enumerate(plist):
                if entry[0] == "mem":
                    off, length = self._file.append(entry[1])
                    plist[i] = ("disk", off, length)
        if self._mem_bytes:
            self.grant.release(self._mem_bytes)
            self._mem_bytes = 0
        SPILL_STATS["spill_rounds"] += 1

    def add(self, part: int, batch: ColumnBatch) -> None:
        if batch.num_rows == 0:
            return
        n = batch.nbytes()
        if self.grant.try_reserve(n):
            self._entries[part].append(("mem", batch))
            self._mem_bytes += n
            return
        self._spill_round()
        if self.grant.try_reserve(n):
            self._entries[part].append(("mem", batch))
            self._mem_bytes += n
        else:
            off, length = self._file.append(batch)
            self._entries[part].append(("disk", off, length))

    def take(self, part: int) -> ColumnBatch:
        """Materialize one partition (chunks in arrival order) and drop
        its buffers. Peak extra memory is one partition's output — the
        chunked-emission contract the worker's accounting asserts."""
        chunks = []
        for entry in self._entries[part]:
            if entry[0] == "mem":
                chunks.append(entry[1])
                self._mem_bytes -= entry[1].nbytes()
                self.grant.release(entry[1].nbytes())
            else:
                chunks.append(self._file.read(entry[1], entry[2]))
        self._entries[part] = []
        batch = ColumnBatch.concat(chunks)
        return batch
