"""Physical query plans: JSON-able pipelines with dependencies (paper §3.2).

A plan is a list of pipelines; each pipeline reads either base-table
partitions or the shuffle output of upstream pipelines, applies a chain of
vectorized operators, and either reshuffles or collects its output. The
coordinator decides fragment counts (data parallelism) per pipeline at
compile time.

Join-as-op pipeline spec: an equi-join is an ordinary entry in ``ops`` —

    {"op": "hash_join", "left_key": "<probe col>", "right_key": "<build col>"}

with the build side declared by the pipeline's ``input2`` (a ShuffleInput
partitioned the same way as ``input``). The worker resolves the build-side
read into the op spec at runtime (a ``"build"`` ColumnBatch, never part of
the JSON), and the execution backends treat the join like any other
pipeline op: the numpy backend interprets ``operators.op_hash_join``
(duplicate build keys expand, SQL inner-join multiplicity); the jit
backend traces the join probe, every following filter/project, and — when
the run reaches a shuffle output — the radix partition assignment as one
compiled call (``engine_compile._FusedTail``). The legacy ``Pipeline.join``
field (``{left_key, right_key}``) is still accepted and is normalized by
the worker into a leading ``hash_join`` op.

Other ops: {"op": "filter", "expr": [...]} | {"op": "project", "columns":
[name | [name, value-expr], ...]} | {"op": "hash_agg", "keys": [...],
"aggs": [[out, fn, col], ...]} | {"op": "udf", "name": ..., "kwargs": ...,
"broadcast": {...}} (see ``operators.py`` for expression grammar).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional


@dataclasses.dataclass
class TableInput:
    table: str
    columns: list[str]
    type: str = "table"


@dataclasses.dataclass
class ShuffleInput:
    from_pipeline: str
    type: str = "shuffle"


@dataclasses.dataclass
class ShuffleOutput:
    partition_by: str
    partitions: int
    type: str = "shuffle"


@dataclasses.dataclass
class CollectOutput:
    type: str = "collect"


@dataclasses.dataclass
class Pipeline:
    name: str
    input: object                       # TableInput | ShuffleInput
    ops: list[dict]
    output: object                      # ShuffleOutput | CollectOutput
    input2: Optional[ShuffleInput] = None
    # legacy {left_key, right_key}; prefer a hash_join op in ``ops``
    join: Optional[dict] = None
    fragments: Optional[int] = None     # fixed parallelism (else coordinator)

    def deps(self) -> list[str]:
        out = []
        for inp in (self.input, self.input2):
            if isinstance(inp, ShuffleInput):
                out.append(inp.from_pipeline)
        return out


@dataclasses.dataclass
class QueryPlan:
    name: str
    pipelines: list[Pipeline]

    def to_json(self) -> str:
        def default(o):
            if dataclasses.is_dataclass(o):
                return dataclasses.asdict(o)
            import numpy as np
            if isinstance(o, np.ndarray):
                return o.tolist()
            if isinstance(o, (np.integer,)):
                return int(o)
            if isinstance(o, (np.floating,)):
                return float(o)
            raise TypeError(type(o))
        return json.dumps(dataclasses.asdict(self), default=default)

    @staticmethod
    def from_json(text: str) -> "QueryPlan":
        raw = json.loads(text)
        pipelines = []
        for p in raw["pipelines"]:
            inp = _input_from(p["input"])
            inp2 = _input_from(p["input2"]) if p.get("input2") else None
            if p["output"]["type"] == "shuffle":
                out = ShuffleOutput(p["output"]["partition_by"],
                                    p["output"]["partitions"])
            else:
                out = CollectOutput()
            pipelines.append(Pipeline(p["name"], inp, p["ops"], out,
                                      input2=inp2, join=p.get("join"),
                                      fragments=p.get("fragments")))
        return QueryPlan(raw["name"], pipelines)


def _input_from(raw: dict):
    if raw["type"] == "table":
        return TableInput(raw["table"], raw["columns"])
    return ShuffleInput(raw["from_pipeline"])
