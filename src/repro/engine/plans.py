"""Physical query plans: JSON-able pipelines with dependencies (paper §3.2).

A plan is a list of pipelines; each pipeline reads either base-table
partitions or the shuffle output of upstream pipelines, applies a chain of
vectorized operators (optionally after an equi-join of two shuffle inputs),
and either reshuffles or collects its output. The coordinator decides
fragment counts (data parallelism) per pipeline at compile time.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional


@dataclasses.dataclass
class TableInput:
    table: str
    columns: list[str]
    type: str = "table"


@dataclasses.dataclass
class ShuffleInput:
    from_pipeline: str
    type: str = "shuffle"


@dataclasses.dataclass
class ShuffleOutput:
    partition_by: str
    partitions: int
    type: str = "shuffle"


@dataclasses.dataclass
class CollectOutput:
    type: str = "collect"


@dataclasses.dataclass
class Pipeline:
    name: str
    input: object                       # TableInput | ShuffleInput
    ops: list[dict]
    output: object                      # ShuffleOutput | CollectOutput
    input2: Optional[ShuffleInput] = None
    join: Optional[dict] = None         # {left_key, right_key}
    fragments: Optional[int] = None     # fixed parallelism (else coordinator)

    def deps(self) -> list[str]:
        out = []
        for inp in (self.input, self.input2):
            if isinstance(inp, ShuffleInput):
                out.append(inp.from_pipeline)
        return out


@dataclasses.dataclass
class QueryPlan:
    name: str
    pipelines: list[Pipeline]

    def to_json(self) -> str:
        def default(o):
            if dataclasses.is_dataclass(o):
                return dataclasses.asdict(o)
            import numpy as np
            if isinstance(o, np.ndarray):
                return o.tolist()
            if isinstance(o, (np.integer,)):
                return int(o)
            if isinstance(o, (np.floating,)):
                return float(o)
            raise TypeError(type(o))
        return json.dumps(dataclasses.asdict(self), default=default)

    @staticmethod
    def from_json(text: str) -> "QueryPlan":
        raw = json.loads(text)
        pipelines = []
        for p in raw["pipelines"]:
            inp = _input_from(p["input"])
            inp2 = _input_from(p["input2"]) if p.get("input2") else None
            if p["output"]["type"] == "shuffle":
                out = ShuffleOutput(p["output"]["partition_by"],
                                    p["output"]["partitions"])
            else:
                out = CollectOutput()
            pipelines.append(Pipeline(p["name"], inp, p["ops"], out,
                                      input2=inp2, join=p.get("join"),
                                      fragments=p.get("fragments")))
        return QueryPlan(raw["name"], pipelines)


def _input_from(raw: dict):
    if raw["type"] == "table":
        return TableInput(raw["table"], raw["columns"])
    return ShuffleInput(raw["from_pipeline"])
