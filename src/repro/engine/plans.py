"""Physical query plans: JSON-able pipelines with dependencies (paper §3.2).

A plan is a list of pipelines; each pipeline reads either base-table
partitions or the shuffle output of upstream pipelines, applies a chain of
vectorized operators, and either reshuffles or collects its output. The
coordinator decides fragment counts (data parallelism) per pipeline at
compile time.

Join-as-op pipeline spec: an equi-join is an ordinary entry in ``ops`` —

    {"op": "hash_join", "left_key": "<probe col>", "right_key": "<build col>"}

with the build side declared by the pipeline's ``input2`` (a ShuffleInput
partitioned the same way as ``input``). The worker resolves the build-side
read into the op spec at runtime (a ``"build"`` ColumnBatch, never part of
the JSON), and the execution backends treat the join like any other
pipeline op: the numpy backend interprets ``operators.op_hash_join``
(duplicate build keys expand, SQL inner-join multiplicity); the jit
backend (the default) traces the join probe — duplicate build keys
included — every following filter/project, and — when the run reaches a
shuffle output — the radix partition assignment as one compiled call
(``engine_compile._FusedTail``); a trailing partial ``hash_agg``
partitioned by one of its own group keys aggregates per partition slice
so the segment still traces whole. The legacy ``Pipeline.join``
field (``{left_key, right_key}``) is still accepted and is normalized by
the worker into a leading ``hash_join`` op.

Other ops: {"op": "filter", "expr": [...]} | {"op": "project", "columns":
[name | [name, value-expr], ...]} | {"op": "hash_agg", "keys": [...],
"aggs": [[out, fn, col], ...]} | {"op": "udf", "name": ..., "kwargs": ...,
"broadcast": {...}} (see ``operators.py`` for expression grammar).

Plans are rarely hand-built anymore: ``engine.logical`` provides the
typed expression/plan builder and ``engine.optimizer`` lowers it to this
physical vocabulary (predicate pushdown, projection pruning, partial/
final aggregate splitting, build-side + fan-out selection). Hand-built
plans remain first-class — ``QueryPlan.validate()`` fail-fast checks
both kinds before the coordinator schedules a single fragment.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Optional

from repro.engine import logical


@dataclasses.dataclass
class TableInput:
    table: str
    columns: list[str]
    type: str = "table"


@dataclasses.dataclass
class ShuffleInput:
    from_pipeline: str
    type: str = "shuffle"


# Exchange tiers a shuffle can ride (core.storage_service stores carry the
# matching ``tier`` attribute): the object store is the bulk default, "kv"
# is the memory-grade fast tier chosen by break-even placement.
EXCHANGE_TIERS = ("object", "kv")


@dataclasses.dataclass
class ShuffleOutput:
    partition_by: str
    partitions: int
    type: str = "shuffle"
    tier: str = "object"


@dataclasses.dataclass
class CollectOutput:
    type: str = "collect"


@dataclasses.dataclass
class Pipeline:
    name: str
    input: object                       # TableInput | ShuffleInput
    ops: list[dict]
    output: object                      # ShuffleOutput | CollectOutput
    # Build side of a hash_join: a ShuffleInput co-partitioned with
    # ``input``, or a TableInput whose stored partition i IS hash
    # partition i (declared layout, ``partitioning2`` required) so the
    # join reads the table's partition slices directly with no shuffle.
    input2: Optional[object] = None
    # legacy {left_key, right_key}; prefer a hash_join op in ``ops``
    join: Optional[dict] = None
    fragments: Optional[int] = None     # fixed parallelism (else coordinator)
    # Input partitioning property the planner RELIED on to elide a shuffle:
    # {"key": <column in the producer's output>, "fanout": n} asserts that
    # fragment i of this pipeline receives exactly the rows with
    # ``hash(key) % fanout == i``. For a ShuffleInput the property must
    # match the producer's ShuffleOutput (validate() checks); for a
    # TableInput it declares that stored partition i IS hash partition i
    # (``logical.Scan.partitioned_by``) and the worker verifies it against
    # the actual key values at runtime. ``partitioning2`` is the same
    # declaration for a TableInput build side (``input2``).
    partitioning: Optional[dict] = None
    partitioning2: Optional[dict] = None

    def deps(self) -> list[str]:
        out = []
        for inp in (self.input, self.input2):
            if isinstance(inp, ShuffleInput):
                out.append(inp.from_pipeline)
        return out


KNOWN_OPS = ("filter", "project", "hash_agg", "hash_join", "udf")


class PlanValidationError(ValueError):
    """A malformed physical plan, caught before any fragment runs."""


def _op_input_columns(op: dict) -> Optional[set]:
    """Columns an op reads from its input batch, or None when opaque
    (UDFs). Uses the logical layer's grammar walkers so plan validation
    and the planner cannot drift on the expression grammar."""
    kind = op.get("op")
    if kind == "filter":
        return logical.pred_columns(op["expr"])
    if kind == "project":
        return logical.project_inputs(op["columns"])
    if kind == "hash_agg":
        return set(op["keys"]) | {col for _, fn, col in op["aggs"]
                                  if fn != "count"}
    if kind == "hash_join":
        return {op["left_key"]}
    return None


def _pipeline_schema(pipe: "Pipeline", schemas: dict,
                     errors: Optional[list] = None) -> Optional[list]:
    """Walk a pipeline's ops advancing the (ordered) output schema;
    returns the output columns, or None when unknowable (UDF ops,
    unknown upstream schema). ``schemas`` maps pipeline name -> schema.
    When ``errors`` is given, validation problems found along the walk
    (unknown ops, op inputs / join keys / legacy-join specs referencing
    columns nothing upstream produces) are appended — one walk serves
    both schema inference and validation, so the two cannot drift."""
    def err(msg: str) -> None:
        if errors is not None:
            errors.append(f"pipeline {pipe.name!r}: {msg}")

    if isinstance(pipe.input, TableInput):
        cols = list(pipe.input.columns)
    else:
        known = schemas.get(pipe.input.from_pipeline)
        cols = None if known is None else list(known)
    ops = list(pipe.ops)
    if pipe.join is not None:   # legacy spec: leading hash_join
        ops.insert(0, {"op": "hash_join", **pipe.join})
    for op in ops:
        kind = op.get("op")
        if kind not in KNOWN_OPS:
            err(f"unknown op {kind!r}")
            continue
        needs = _op_input_columns(op)
        if cols is not None and needs is not None \
                and not needs <= set(cols):
            err(f"{kind} op reads column(s) {sorted(needs - set(cols))} "
                f"not produced upstream (have {sorted(cols)})")
        if kind == "project":
            cols = [c if isinstance(c, str) else c[0] for c in op["columns"]]
        elif kind == "hash_agg":
            cols = list(op["keys"]) + [a[0] for a in op["aggs"]]
        elif kind == "hash_join":
            if pipe.input2 is None:
                build = None
            elif isinstance(pipe.input2, TableInput):
                build = list(pipe.input2.columns)
            else:
                build = schemas.get(pipe.input2.from_pipeline)
            if build is not None and op.get("right_key") not in build:
                err(f"hash_join right_key {op.get('right_key')!r} not "
                    f"produced by build side (have {sorted(build)})")
            cols = logical.join_output_schema(cols, build,
                                              op.get("right_key"))
        elif kind == "udf":
            cols = None
        # filter: schema unchanged
    return cols


def pipeline_schemas(plan: "QueryPlan") -> dict[str, Optional[list]]:
    """Output schema of every pipeline (name -> ordered columns, or None
    when unknowable, e.g. past a UDF). The adaptive executor uses this to
    decide whether a runtime build-side flip can emit its key-restoring
    rename; hand-built tools get the same walk ``validate()`` performs."""
    schemas: dict[str, Optional[list]] = {}
    for p in plan.pipelines:
        schemas[p.name] = _pipeline_schema(p, schemas)
    return schemas


def _check_partitioning(pipe: "Pipeline", by_name: dict) -> list[str]:
    """Structural checks for a declared (relied-on) input partitioning:
    the property must be exactly what the upstream shuffle established —
    an elided stage with a wrong property silently drops or duplicates
    groups, so this fails fast instead."""
    part = pipe.partitioning
    errs = []
    if not isinstance(part, dict) or "key" not in part \
            or "fanout" not in part:
        return [f"malformed partitioning {part!r} "
                "(need {'key': ..., 'fanout': ...})"]
    if isinstance(pipe.input, ShuffleInput):
        prod = by_name.get(pipe.input.from_pipeline)
        if prod is not None and isinstance(prod.output, ShuffleOutput):
            if prod.output.partition_by != part["key"]:
                errs.append(
                    f"partitioning key {part['key']!r} does not match "
                    f"producer {prod.name!r}'s shuffle partition key "
                    f"{prod.output.partition_by!r}")
            if prod.output.partitions != part["fanout"]:
                errs.append(
                    f"partitioning fan-out {part['fanout']} does not "
                    f"match producer {prod.name!r}'s "
                    f"{prod.output.partitions} shuffle partitions")
    elif isinstance(pipe.input, TableInput):
        # A declared pre-partitioned base table: the key must be scanned
        # (the worker verifies values % fanout at runtime) and the
        # fragment count must be pinned to the fan-out so stored
        # partition i lands on fragment i.
        if part["key"] not in pipe.input.columns:
            errs.append(f"partitioning key {part['key']!r} is not among "
                        f"the scanned columns {pipe.input.columns}")
        if pipe.fragments != part["fanout"]:
            errs.append(
                f"declared table partitioning fan-out {part['fanout']} "
                f"requires fragments={part['fanout']} "
                f"(got {pipe.fragments!r})")
    return errs


@dataclasses.dataclass
class QueryPlan:
    name: str
    pipelines: list[Pipeline]

    def validate(self) -> None:
        """Fail-fast structural checks, run by the coordinator before
        scheduling: duplicate pipeline names, dangling or out-of-order
        ``ShuffleInput.from_pipeline`` references, unknown op names,
        ``hash_join`` without a build-side ``input2`` (or with more than
        one join per pipeline), join inputs whose producers shuffle at
        different fan-outs, declared ``partitioning`` properties that
        disagree with the upstream shuffle (elided stages), op inputs and
        shuffle partition keys no upstream op produces, and a terminal
        pipeline that never collects. Raises ``PlanValidationError`` listing every problem —
        these misfires otherwise surface as opaque KeyErrors deep in
        ``worker.py``."""
        errors: list[str] = []
        if not self.pipelines:
            raise PlanValidationError(f"plan {self.name!r} has no pipelines")
        by_name = {q.name: q for q in self.pipelines}
        seen: list[str] = []
        for p in self.pipelines:
            if p.name in seen:
                errors.append(f"duplicate pipeline name {p.name!r}")
            for dep in p.deps():
                if dep not in seen:
                    tag = "dangling" if dep not in by_name else \
                        "out-of-order (must be defined earlier)"
                    errors.append(f"pipeline {p.name!r}: {tag} shuffle "
                                  f"input from_pipeline={dep!r}")
                elif not isinstance(by_name[dep].output, ShuffleOutput):
                    # A collect-output producer never writes shuffle
                    # objects: the consumer would read nothing, silently
                    # (missing_ok) on the build side.
                    errors.append(
                        f"pipeline {p.name!r}: shuffle input reads "
                        f"{dep!r}, which does not produce a shuffle "
                        f"output ({type(by_name[dep].output).__name__})")
            seen.append(p.name)
        schemas: dict = {}
        for p in self.pipelines:
            n_joins = (1 if p.join is not None else 0) + \
                sum(1 for op in p.ops if op.get("op") == "hash_join")
            if n_joins and p.input2 is None:
                errors.append(f"pipeline {p.name!r}: hash_join without a "
                              "build-side input2")
            if n_joins > 1:
                # One build-side input per pipeline: a second hash_join op
                # (e.g. from a botched join elision) would silently probe
                # the wrong build batch.
                errors.append(f"pipeline {p.name!r}: {n_joins} hash_join "
                              "ops but only one build-side input2")
            if n_joins and isinstance(p.input, ShuffleInput) \
                    and isinstance(p.input2, ShuffleInput):
                # Join inputs must be co-partitioned: fragment i probes
                # partition i of both sides, so differing fan-outs pair
                # probe rows with the wrong build slice.
                prod = by_name.get(p.input.from_pipeline)
                prod2 = by_name.get(p.input2.from_pipeline)
                if prod is not None and prod2 is not None \
                        and isinstance(prod.output, ShuffleOutput) \
                        and isinstance(prod2.output, ShuffleOutput) \
                        and prod.output.partitions != prod2.output.partitions:
                    errors.append(
                        f"pipeline {p.name!r}: join inputs are not "
                        f"co-partitioned ({prod.name!r} shuffles "
                        f"{prod.output.partitions} partitions, "
                        f"{prod2.name!r} shuffles "
                        f"{prod2.output.partitions})")
            if isinstance(p.input2, TableInput):
                # A base table as build side only works when its stored
                # partitions ARE the join's hash partitions — the planner
                # must have declared (and the worker will verify) that.
                if p.partitioning2 is None:
                    errors.append(
                        f"pipeline {p.name!r}: TableInput build side "
                        f"({p.input2.table!r}) without a declared "
                        "partitioning2 — its stored partitions cannot be "
                        "assumed to be hash partitions")
                elif p.partitioning2.get("key") not in p.input2.columns:
                    errors.append(
                        f"pipeline {p.name!r}: partitioning2 key "
                        f"{p.partitioning2.get('key')!r} is not among the "
                        f"build-side columns {p.input2.columns}")
            if p.partitioning is not None:
                errors.extend(
                    f"pipeline {p.name!r}: {m}"
                    for m in _check_partitioning(p, by_name))
            if isinstance(p.output, ShuffleOutput) \
                    and p.output.tier not in EXCHANGE_TIERS:
                errors.append(
                    f"pipeline {p.name!r}: unknown exchange tier "
                    f"{p.output.tier!r} (expected one of {EXCHANGE_TIERS})")
            schema = _pipeline_schema(p, schemas, errors)
            schemas[p.name] = schema
            if isinstance(p.output, ShuffleOutput) and schema is not None \
                    and p.output.partition_by not in schema:
                errors.append(
                    f"pipeline {p.name!r}: shuffle partition key "
                    f"{p.output.partition_by!r} is not produced upstream "
                    f"(have {schema})")
        if not isinstance(self.pipelines[-1].output, CollectOutput):
            errors.append(f"terminal pipeline "
                          f"{self.pipelines[-1].name!r} must collect "
                          "(the coordinator merges its fragments)")
        if errors:
            raise PlanValidationError(
                f"invalid plan {self.name!r}:\n  " + "\n  ".join(errors))

    def to_json(self) -> str:
        def default(o):
            if dataclasses.is_dataclass(o):
                return dataclasses.asdict(o)
            import numpy as np
            if isinstance(o, np.ndarray):
                return o.tolist()
            if isinstance(o, (np.integer,)):
                return int(o)
            if isinstance(o, (np.floating,)):
                return float(o)
            raise TypeError(type(o))
        return json.dumps(dataclasses.asdict(self), default=default)

    @staticmethod
    def from_json(text: str) -> "QueryPlan":
        raw = json.loads(text)
        pipelines = []
        for p in raw["pipelines"]:
            inp = _input_from(p["input"])
            inp2 = _input_from(p["input2"]) if p.get("input2") else None
            if p["output"]["type"] == "shuffle":
                out = ShuffleOutput(p["output"]["partition_by"],
                                    p["output"]["partitions"],
                                    tier=p["output"].get("tier", "object"))
            else:
                out = CollectOutput()
            pipelines.append(Pipeline(p["name"], inp, p["ops"], out,
                                      input2=inp2, join=p.get("join"),
                                      fragments=p.get("fragments"),
                                      partitioning=p.get("partitioning"),
                                      partitioning2=p.get("partitioning2")))
        return QueryPlan(raw["name"], pipelines)


def _input_from(raw: dict):
    if raw["type"] == "table":
        return TableInput(raw["table"], raw["columns"])
    return ShuffleInput(raw["from_pipeline"])


# ---------------------------------------------------------------------------
# Canonical plan shape (compiled-plan cache keys)
# ---------------------------------------------------------------------------
#
# Two queries share compiled traces when they agree on everything XLA
# specializes on — op structure, referenced column names, shuffle fan-outs,
# literal dtype classes and in-list lengths — regardless of the literal
# VALUES (filter constants, projection coefficients) and table names. The
# canonicalizer below splits a plan along exactly that line: scalar/list
# literals in filter and project expressions are replaced by positional
# ``[LIT, index, dtype-tag]`` placeholder nodes and collected into a
# side list, table names are renamed positionally, pipeline names are
# renamed positionally (they embed the query name). ``plan_shape_hash``
# is a sha256 over the canonical JSON — a pure function of plan
# structure, stable across processes (no use of Python's salted
# ``hash``); ``plan_literal_hash`` covers everything the shape hash
# deliberately leaves out, so (shape, literal) identifies a query's
# exact semantics for result caching.
#
# Placeholders occupy literal slots ONLY (comparison right-hand sides,
# ``between`` bounds, ``in``/``case_in`` value lists, ``const`` payloads)
# so the grammar walkers in ``logical`` (``pred_columns``,
# ``value_columns``) traverse canonical expressions unchanged. The jit
# backend (``engine.compile``) re-binds placeholders at call time —
# to traced scalars inside a jit trace, to the original Python values on
# interpreted fallbacks — so literal values never bake into a trace.

LIT = "__lit__"


def _pyval(v):
    """Plain-Python view of a literal (numpy scalars -> Python scalars) so
    canonical JSON and tags do not depend on who built the plan."""
    return v.item() if hasattr(v, "item") else v


def _scalar_tag(v) -> Optional[str]:
    if isinstance(v, bool):
        return "b"
    if isinstance(v, int):
        return "i"
    if isinstance(v, float):
        return "f"
    return None   # non-numeric literals stay structural


def _ph(v, lits: list):
    v = _pyval(v)
    tag = _scalar_tag(v)
    if tag is None:
        return v
    lits.append(v)
    return [LIT, len(lits) - 1, tag]


def _ph_list(vals, lits: list):
    pv = [_pyval(v) for v in vals]
    tags = [_scalar_tag(v) for v in pv]
    if not pv or any(t is None for t in tags):
        return list(vals)
    # The list length is structural (it is the shape of the traced isin
    # constant); the element dtype class is structural too.
    if "f" in tags:
        kind = "f"
    elif all(t == "b" for t in tags):
        kind = "b"
    else:
        kind = "i"
    lits.append(pv)
    return [LIT, len(lits) - 1, f"{kind}{len(pv)}"]


def _canon_pred(expr, lits: list):
    op = expr[0]
    if op in ("and", "or"):
        return [op] + [_canon_pred(s, lits) for s in expr[1:]]
    if op == "between":
        return [op, expr[1], _ph(expr[2], lits), _ph(expr[3], lits)]
    if op == "in":
        return [op, expr[1], _ph_list(expr[2], lits)]
    if op == "ltcol":
        return list(expr)
    # lt | le | ge | gt | eq | ne
    return [op, expr[1], _ph(expr[2], lits)]


def _canon_value(expr, lits: list):
    if isinstance(expr, str):
        return expr
    op = expr[0]
    if op == "const":
        return [op, _ph(expr[1], lits)]
    if op in ("mul", "add", "sub", "div"):
        return [op, _canon_value(expr[1], lits), _canon_value(expr[2], lits)]
    if op in ("sub1", "add1"):
        return [op, _canon_value(expr[1], lits)]
    if op == "case_in":
        return [op, expr[1], _ph_list(expr[2], lits)] + list(expr[3:])
    return [_pyval(x) if not isinstance(x, (list, str)) else x
            for x in expr]


def canonicalize_ops(ops: list[dict], lits: Optional[list] = None
                     ) -> tuple[list[dict], list]:
    """Split the literals out of an op chain. Returns ``(canonical_ops,
    literals)``: filter/project expressions carry ``[LIT, i, tag]``
    placeholder nodes, ``literals[i]`` holds the original value (a scalar,
    or the whole list for ``in``/``case_in``). Other ops (hash_join,
    hash_agg, udf) are structural and pass through copied."""
    lits = [] if lits is None else lits
    out = []
    for op in ops:
        kind = op.get("op")
        if kind == "filter":
            out.append({"op": "filter", "expr": _canon_pred(op["expr"],
                                                            lits)})
        elif kind == "project":
            cols = [c if isinstance(c, str)
                    else [c[0], _canon_value(c[1], lits)]
                    for c in op["columns"]]
            out.append({"op": "project", "columns": cols})
        else:
            out.append(dict(op))
    return out, lits


def canonical_plan(plan: "QueryPlan") -> tuple[dict, dict]:
    """Canonical (shape, residue) decomposition of a plan. ``shape`` is
    the deterministic JSON-able structure two trace-sharing queries agree
    on; ``residue`` holds what the shape hash leaves out: the literal
    values (in placeholder order), the positional->actual table name map,
    and the plan/pipeline names."""
    pipe_names = {p.name: f"p{i}" for i, p in enumerate(plan.pipelines)}
    tables: dict[str, str] = {}
    lits: list = []

    def table_alias(t: str) -> str:
        if t not in tables:
            tables[t] = f"t{len(tables)}"
        return tables[t]

    def canon_input(inp):
        if inp is None:
            return None
        if isinstance(inp, TableInput):
            return {"type": "table", "table": table_alias(inp.table),
                    "columns": list(inp.columns)}
        return {"type": "shuffle", "from": pipe_names[inp.from_pipeline]}

    pipes = []
    for p in plan.pipelines:
        ops = list(p.ops)
        if p.join is not None:   # normalize the legacy join spec
            ops.insert(0, {"op": "hash_join", **p.join})
        cops, lits = canonicalize_ops(ops, lits)
        if isinstance(p.output, ShuffleOutput):
            # The tier is part of the canonical shape: a cached compiled
            # plan routed to the wrong exchange tier would read shuffle
            # objects that were never written there.
            out = {"type": "shuffle", "by": p.output.partition_by,
                   "partitions": p.output.partitions,
                   "tier": p.output.tier}
        else:
            out = {"type": "collect"}
        pipes.append({"name": pipe_names[p.name],
                      "input": canon_input(p.input),
                      "input2": canon_input(p.input2),
                      "ops": cops, "output": out,
                      "fragments": p.fragments,
                      "partitioning": p.partitioning,
                      "partitioning2": p.partitioning2})
    shape = {"pipelines": pipes}
    residue = {"name": plan.name,
               "tables": {alias: t for t, alias in tables.items()},
               "literals": lits}
    return shape, residue


def _sha(obj) -> str:
    text = json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=str)
    return hashlib.sha256(text.encode()).hexdigest()


def plan_shape_hash(plan: "QueryPlan") -> str:
    """Deterministic (cross-process) hash of a plan's canonical shape:
    structure, column names, fan-outs, literal dtype classes — NOT
    literal values, table names, or the query name. Queries with equal
    shape hashes share every compiled trace of the jit backend."""
    shape, _ = canonical_plan(plan)
    return _sha(shape)


def plan_cache_key(plan: "QueryPlan") -> tuple[str, str]:
    """``(shape_hash, literal_hash)`` in one canonicalization pass. The
    pair identifies a query's exact semantics up to the data it reads:
    the shape hash keys the compiled-plan (trace) cache, the pair keys
    the serving layer's result cache (alongside table etags)."""
    shape, residue = canonical_plan(plan)
    return _sha(shape), _sha(residue)
