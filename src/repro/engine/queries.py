"""The paper's query suite: TPC-H Q1, Q6, Q12 and TPCx-BB Q3 (§3.1).

I/O-heavy queries chosen by the paper to expose resource behaviour: Q1/Q6
select-project-aggregate, Q12 and Q3 join with broad operator sets
including UDFs. Each query is authored on the logical builder
(``engine.logical``) and lowered through the optimizer
(``engine.optimizer``) into the physical plan the coordinator schedules:
``qX_logical`` returns the declarative ``LogicalQuery``; ``qX_plan``
lowers it (projection pruning, predicate pushdown, partial/final
aggregate split, build-side + fan-out selection) for callers that want
the physical ``QueryPlan`` directly. Pure-numpy reference
implementations ride along for correctness tests; the pre-logical
hand-built plans live on as golden parity fixtures in
``tests/golden_plans.py``.
"""
from __future__ import annotations

import numpy as np

from repro.engine import datagen, optimizer
from repro.engine.columnar import ColumnBatch
from repro.engine.logical import LogicalQuery, col, count_, scan, sum_
from repro.engine.plans import QueryPlan

# dictionary codes (columnar.DICTIONARIES)
MAIL, SHIP = 2, 5
URGENT, HIGH = 0, 1
VIEW, PURCHASE = 0, 2


# ---------------------------------------------------------------------------
# TPC-H Q6 — scan-heavy filter + global aggregate
# ---------------------------------------------------------------------------

def q6_logical(shipdate_lo: int = datagen.DATE_1994_01_01,
               discount: float = 0.06,
               quantity: float = 24.0) -> LogicalQuery:
    return (
        scan("lineitem")
        .filter((col("l_shipdate") >= shipdate_lo)
                & (col("l_shipdate") < shipdate_lo + 365)
                & col("l_discount").between(round(discount - 0.01, 2),
                                            round(discount + 0.01, 2))
                & (col("l_quantity") < quantity))
        .select((col("l_extendedprice") * col("l_discount"))
                .alias("revenue"))
        .agg(sum_("revenue").alias("revenue"))
        .collect("tpch_q6"))


def q6_plan(shipdate_lo: int = datagen.DATE_1994_01_01,
            discount: float = 0.06, quantity: float = 24.0) -> QueryPlan:
    return optimizer.plan(q6_logical(shipdate_lo, discount, quantity))


def q6_reference(lineitem: ColumnBatch,
                 shipdate_lo: int = datagen.DATE_1994_01_01,
                 discount: float = 0.06, quantity: float = 24.0) -> float:
    m = ((lineitem["l_shipdate"] >= shipdate_lo)
         & (lineitem["l_shipdate"] < shipdate_lo + 365)
         & (lineitem["l_discount"] >= round(discount - 0.01, 2))
         & (lineitem["l_discount"] <= round(discount + 0.01, 2))
         & (lineitem["l_quantity"] < quantity))
    return float(np.sum(lineitem["l_extendedprice"][m]
                        * lineitem["l_discount"][m]))


# ---------------------------------------------------------------------------
# TPC-H Q1 — scan-heavy grouped aggregation
# ---------------------------------------------------------------------------

def q1_logical(delta_days: int = 90) -> LogicalQuery:
    cutoff = datagen.DATE_MAX - delta_days
    disc_price = col("l_extendedprice") * (1 - col("l_discount"))
    charge = (col("l_extendedprice") * (1 - col("l_discount"))) \
        * (1 + col("l_tax"))
    return (
        scan("lineitem")
        .filter(col("l_shipdate") <= cutoff)
        .select("l_returnflag", "l_linestatus", "l_quantity",
                "l_extendedprice", "l_discount",
                disc_price.alias("disc_price"), charge.alias("charge"))
        .group_by("l_returnflag", "l_linestatus")
        .agg(sum_("l_quantity").alias("sum_qty"),
             sum_("l_extendedprice").alias("sum_base_price"),
             sum_("disc_price").alias("sum_disc_price"),
             sum_("charge").alias("sum_charge"),
             sum_("l_discount").alias("sum_disc"),
             # Count partials re-aggregate as sums downstream — the
             # optimizer's agg-split pass owns that mapping.
             count_("l_quantity").alias("count_order"))
        .collect("tpch_q1"))


def q1_plan(delta_days: int = 90) -> QueryPlan:
    return optimizer.plan(q1_logical(delta_days))


def q1_reference(lineitem: ColumnBatch, delta_days: int = 90) -> ColumnBatch:
    cutoff = datagen.DATE_MAX - delta_days
    m = lineitem["l_shipdate"] <= cutoff
    li = lineitem.select(m)
    disc_price = li["l_extendedprice"] * (1 - li["l_discount"])
    charge = disc_price * (1 + li["l_tax"])
    keys = li["l_returnflag"].astype(np.int64) * 2 \
        + li["l_linestatus"].astype(np.int64)
    uniq, inv = np.unique(keys, return_inverse=True)
    def agg(x):
        return np.bincount(inv, weights=x, minlength=len(uniq))
    return ColumnBatch({
        "l_returnflag": (uniq // 2).astype(np.int8),
        "l_linestatus": (uniq % 2).astype(np.int8),
        "sum_qty": agg(li["l_quantity"]),
        "sum_base_price": agg(li["l_extendedprice"]),
        "sum_disc_price": agg(disc_price),
        "sum_charge": agg(charge),
        "sum_disc": agg(li["l_discount"]),
        "count_order": np.bincount(inv, minlength=len(uniq)),
    })


# ---------------------------------------------------------------------------
# TPC-H Q12 — join + grouped conditional aggregation (shuffle-heavy)
# ---------------------------------------------------------------------------

def q12_logical(shuffle_partitions: int | None = 8,
                year_lo: int = datagen.DATE_1994_01_01) -> LogicalQuery:
    lineitem = (
        scan("lineitem")
        .filter(col("l_shipmode").isin([MAIL, SHIP])
                & (col("l_commitdate") < col("l_receiptdate"))
                & (col("l_shipdate") < col("l_commitdate"))
                & (col("l_receiptdate") >= year_lo)
                & (col("l_receiptdate") < year_lo + 365))
        .select("l_orderkey", "l_shipmode"))
    orders = scan("orders").select("o_orderkey", "o_orderpriority")
    high = col("o_orderpriority").case_in([URGENT, HIGH])
    return (
        lineitem
        .join(orders, on=("l_orderkey", "o_orderkey"))
        .select("l_shipmode", high.alias("high_line"),
                (1 - high).alias("low_line"))
        .group_by("l_shipmode")
        .agg(sum_("high_line").alias("high_line_count"),
             sum_("low_line").alias("low_line_count"))
        .collect("tpch_q12", shuffle_partitions=shuffle_partitions))


def q12_plan(shuffle_partitions: int = 8,
             year_lo: int = datagen.DATE_1994_01_01) -> QueryPlan:
    return optimizer.plan(q12_logical(shuffle_partitions, year_lo))


def q12_reference(lineitem: ColumnBatch, orders: ColumnBatch,
                  year_lo: int = datagen.DATE_1994_01_01) -> ColumnBatch:
    m = (np.isin(lineitem["l_shipmode"], [MAIL, SHIP])
         & (lineitem["l_commitdate"] < lineitem["l_receiptdate"])
         & (lineitem["l_shipdate"] < lineitem["l_commitdate"])
         & (lineitem["l_receiptdate"] >= year_lo)
         & (lineitem["l_receiptdate"] < year_lo + 365))
    li = lineitem.select(m)
    omap = dict(zip(orders["o_orderkey"].tolist(),
                    orders["o_orderpriority"].tolist()))
    prio = np.asarray([omap.get(int(k), -1) for k in li["l_orderkey"]])
    keep = prio >= 0
    shipmode = li["l_shipmode"][keep]
    high = np.isin(prio[keep], [URGENT, HIGH]).astype(np.float64)
    uniq, inv = np.unique(shipmode, return_inverse=True)
    return ColumnBatch({
        "l_shipmode": uniq,
        "high_line_count": np.bincount(inv, weights=high,
                                       minlength=len(uniq)),
        "low_line_count": np.bincount(inv, weights=1.0 - high,
                                      minlength=len(uniq)),
    })


# ---------------------------------------------------------------------------
# TPCx-BB Q3 — MapReduce-style UDF job over clickstreams
# ---------------------------------------------------------------------------

def bb_q3_logical(item_table_key: str, target_category: int = 3,
                  window: int = 5,
                  shuffle_partitions: int | None = 8) -> LogicalQuery:
    """``shuffle_partitions`` only pins row shuffles; this query has
    none after the agg-split optimization (the map pipeline partially
    aggregates, so the combine fan-out is optimizer-owned)."""
    return (
        scan("clickstreams", ["wcs_user_sk", "wcs_click_date_sk",
                              "wcs_click_time_sk", "wcs_item_sk",
                              "wcs_click_type"])
        .map_udf("clicks_before_purchase",
                 kwargs={"target_category": target_category,
                         "window": window},
                 broadcast={"item_categories": {"key": item_table_key,
                                                "column": "i_category_id"}},
                 output_columns=["viewed_item", "n"])
        .group_by("viewed_item")
        .agg(sum_("n").alias("views"))
        .collect("tpcxbb_q3", shuffle_partitions=shuffle_partitions))


def bb_q3_plan(item_table_key: str, target_category: int = 3,
               window: int = 5, shuffle_partitions: int = 8,
               top_k: int = 10) -> QueryPlan:
    return optimizer.plan(bb_q3_logical(item_table_key, target_category,
                                        window, shuffle_partitions))


def bb_q3_reference(clicks: ColumnBatch, item: ColumnBatch,
                    target_category: int = 3, window: int = 5
                    ) -> dict[int, int]:
    order = np.lexsort((clicks["wcs_click_time_sk"],
                        clicks["wcs_click_date_sk"], clicks["wcs_user_sk"]))
    user = clicks["wcs_user_sk"][order]
    item_sk = clicks["wcs_item_sk"][order]
    ctype = clicks["wcs_click_type"][order]
    cats = item["i_category_id"]
    counts: dict[int, int] = {}
    for p in np.flatnonzero((ctype == PURCHASE)
                            & (cats[item_sk] == target_category)):
        lo = max(0, p - window)
        for j in range(lo, p):
            if user[j] == user[p] and ctype[j] == VIEW:
                counts[int(item_sk[j])] = counts.get(int(item_sk[j]), 0) + 1
    return counts


QUERY_BUILDERS = {
    "q1": q1_plan,
    "q6": q6_plan,
    "q12": q12_plan,
}

# Logical builders by canonical name (and short alias) for tooling such
# as ``python -m repro.engine.explain``. TPCx-BB Q3 needs a broadcast
# item-table key; tooling passes a placeholder.
LOGICAL_BUILDERS = {
    "tpch_q1": q1_logical,
    "tpch_q6": q6_logical,
    "tpch_q12": q12_logical,
    "q1": q1_logical,
    "q6": q6_logical,
    "q12": q12_logical,
    "tpcxbb_q3": lambda: bb_q3_logical("tables/item/part-00000"),
    "bb_q3": lambda: bb_q3_logical("tables/item/part-00000"),
}
