"""The paper's query suite: TPC-H Q1, Q6, Q12 and TPCx-BB Q3 (§3.1).

I/O-heavy queries chosen by the paper to expose resource behaviour: Q1/Q6
select-project-aggregate, Q12 and Q3 join with broad operator sets
including UDFs. Each builder returns a (QueryPlan, finalize) pair, plus a
pure-numpy reference implementation for correctness tests.
"""
from __future__ import annotations

import numpy as np

from repro.engine import datagen
from repro.engine.columnar import ColumnBatch
from repro.engine.plans import (CollectOutput, Pipeline, QueryPlan,
                                ShuffleInput, ShuffleOutput, TableInput)

# dictionary codes (columnar.DICTIONARIES)
MAIL, SHIP = 2, 5
URGENT, HIGH = 0, 1
VIEW, PURCHASE = 0, 2


# ---------------------------------------------------------------------------
# TPC-H Q6 — scan-heavy filter + global aggregate
# ---------------------------------------------------------------------------

def q6_plan(shipdate_lo: int = datagen.DATE_1994_01_01,
            discount: float = 0.06, quantity: float = 24.0) -> QueryPlan:
    pred = ["and",
            ["ge", "l_shipdate", shipdate_lo],
            ["lt", "l_shipdate", shipdate_lo + 365],
            ["between", "l_discount", round(discount - 0.01, 2),
             round(discount + 0.01, 2)],
            ["lt", "l_quantity", quantity]]
    scan = Pipeline(
        name="scan_lineitem",
        input=TableInput("lineitem", ["l_shipdate", "l_discount",
                                      "l_quantity", "l_extendedprice"]),
        ops=[{"op": "filter", "expr": pred},
             {"op": "project",
              "columns": [["revenue", ["mul", "l_extendedprice",
                                       "l_discount"]]]},
             {"op": "hash_agg", "keys": [],
              "aggs": [["revenue", "sum", "revenue"]]}],
        output=CollectOutput())
    final = Pipeline(
        name="final_agg",
        input=ShuffleInput("scan_lineitem"),
        ops=[{"op": "hash_agg", "keys": [],
              "aggs": [["revenue", "sum", "revenue"]]}],
        output=CollectOutput())
    # scan collects partials; final reads collected results: model as a
    # 1-partition shuffle for uniformity.
    scan.output = ShuffleOutput(partition_by="__zero__", partitions=1)
    scan.ops.append({"op": "project",
                     "columns": ["revenue",
                                 ["__zero__", ["const", 0]]]})
    return QueryPlan("tpch_q6", [scan, final])


def q6_reference(lineitem: ColumnBatch,
                 shipdate_lo: int = datagen.DATE_1994_01_01,
                 discount: float = 0.06, quantity: float = 24.0) -> float:
    m = ((lineitem["l_shipdate"] >= shipdate_lo)
         & (lineitem["l_shipdate"] < shipdate_lo + 365)
         & (lineitem["l_discount"] >= round(discount - 0.01, 2))
         & (lineitem["l_discount"] <= round(discount + 0.01, 2))
         & (lineitem["l_quantity"] < quantity))
    return float(np.sum(lineitem["l_extendedprice"][m]
                        * lineitem["l_discount"][m]))


# ---------------------------------------------------------------------------
# TPC-H Q1 — scan-heavy grouped aggregation
# ---------------------------------------------------------------------------

_Q1_AGGS = [["sum_qty", "sum", "l_quantity"],
            ["sum_base_price", "sum", "l_extendedprice"],
            ["sum_disc_price", "sum", "disc_price"],
            ["sum_charge", "sum", "charge"],
            ["sum_disc", "sum", "l_discount"],
            ["count_order", "count", "l_quantity"]]


def q1_plan(delta_days: int = 90) -> QueryPlan:
    cutoff = datagen.DATE_MAX - delta_days
    scan = Pipeline(
        name="scan_lineitem",
        input=TableInput("lineitem", ["l_shipdate", "l_quantity",
                                      "l_extendedprice", "l_discount",
                                      "l_tax", "l_returnflag",
                                      "l_linestatus"]),
        ops=[{"op": "filter", "expr": ["le", "l_shipdate", cutoff]},
             {"op": "project", "columns": [
                 "l_returnflag", "l_linestatus", "l_quantity",
                 "l_extendedprice", "l_discount",
                 ["disc_price", ["mul", "l_extendedprice",
                                 ["sub1", "l_discount"]]],
                 ["charge", ["mul", ["mul", "l_extendedprice",
                                     ["sub1", "l_discount"]],
                             ["add1", "l_tax"]]]]},
             {"op": "hash_agg", "keys": ["l_returnflag", "l_linestatus"],
              "aggs": _Q1_AGGS}],
        output=ShuffleOutput(partition_by="l_returnflag", partitions=1))
    final_aggs = [[name, "sum" if fn != "count" else "sum", name]
                  for name, fn, _ in _Q1_AGGS]
    final = Pipeline(
        name="final_agg",
        input=ShuffleInput("scan_lineitem"),
        ops=[{"op": "hash_agg", "keys": ["l_returnflag", "l_linestatus"],
              "aggs": final_aggs}],
        output=CollectOutput())
    return QueryPlan("tpch_q1", [scan, final])


def q1_reference(lineitem: ColumnBatch, delta_days: int = 90) -> ColumnBatch:
    cutoff = datagen.DATE_MAX - delta_days
    m = lineitem["l_shipdate"] <= cutoff
    li = lineitem.select(m)
    disc_price = li["l_extendedprice"] * (1 - li["l_discount"])
    charge = disc_price * (1 + li["l_tax"])
    keys = li["l_returnflag"].astype(np.int64) * 2 \
        + li["l_linestatus"].astype(np.int64)
    uniq, inv = np.unique(keys, return_inverse=True)
    def agg(x):
        return np.bincount(inv, weights=x, minlength=len(uniq))
    return ColumnBatch({
        "l_returnflag": (uniq // 2).astype(np.int8),
        "l_linestatus": (uniq % 2).astype(np.int8),
        "sum_qty": agg(li["l_quantity"]),
        "sum_base_price": agg(li["l_extendedprice"]),
        "sum_disc_price": agg(disc_price),
        "sum_charge": agg(charge),
        "sum_disc": agg(li["l_discount"]),
        "count_order": np.bincount(inv, minlength=len(uniq)),
    })


# ---------------------------------------------------------------------------
# TPC-H Q12 — join + grouped conditional aggregation (shuffle-heavy)
# ---------------------------------------------------------------------------

def q12_plan(shuffle_partitions: int = 8,
             year_lo: int = datagen.DATE_1994_01_01) -> QueryPlan:
    li_scan = Pipeline(
        name="scan_lineitem",
        input=TableInput("lineitem", ["l_orderkey", "l_shipmode",
                                      "l_shipdate", "l_commitdate",
                                      "l_receiptdate"]),
        ops=[{"op": "filter", "expr": ["and",
              ["in", "l_shipmode", [MAIL, SHIP]],
              ["ltcol", "l_commitdate", "l_receiptdate"],
              ["ltcol", "l_shipdate", "l_commitdate"],
              ["ge", "l_receiptdate", year_lo],
              ["lt", "l_receiptdate", year_lo + 365]]},
             {"op": "project", "columns": ["l_orderkey", "l_shipmode"]}],
        output=ShuffleOutput(partition_by="l_orderkey",
                             partitions=shuffle_partitions))
    o_scan = Pipeline(
        name="scan_orders",
        input=TableInput("orders", ["o_orderkey", "o_orderpriority"]),
        ops=[{"op": "project", "columns": ["o_orderkey", "o_orderpriority"]}],
        output=ShuffleOutput(partition_by="o_orderkey",
                             partitions=shuffle_partitions))
    join = Pipeline(
        name="join_agg",
        input=ShuffleInput("scan_lineitem"),
        input2=ShuffleInput("scan_orders"),
        ops=[{"op": "hash_join", "left_key": "l_orderkey",
              "right_key": "o_orderkey"},
             {"op": "project", "columns": [
                 "l_shipmode",
                 ["high_line", ["case_in", "o_orderpriority",
                                [URGENT, HIGH]]],
                 ["low_line", ["sub1", ["case_in", "o_orderpriority",
                                        [URGENT, HIGH]]]]]},
             {"op": "hash_agg", "keys": ["l_shipmode"],
              "aggs": [["high_line_count", "sum", "high_line"],
                       ["low_line_count", "sum", "low_line"]]},
             {"op": "project", "columns": [
                 "l_shipmode", "high_line_count", "low_line_count",
                 ["__zero__", ["const", 0]]]}],
        output=ShuffleOutput(partition_by="__zero__", partitions=1))
    final = Pipeline(
        name="final_agg",
        input=ShuffleInput("join_agg"),
        ops=[{"op": "hash_agg", "keys": ["l_shipmode"],
              "aggs": [["high_line_count", "sum", "high_line_count"],
                       ["low_line_count", "sum", "low_line_count"]]}],
        output=CollectOutput())
    return QueryPlan("tpch_q12", [li_scan, o_scan, join, final])


def q12_reference(lineitem: ColumnBatch, orders: ColumnBatch,
                  year_lo: int = datagen.DATE_1994_01_01) -> ColumnBatch:
    m = (np.isin(lineitem["l_shipmode"], [MAIL, SHIP])
         & (lineitem["l_commitdate"] < lineitem["l_receiptdate"])
         & (lineitem["l_shipdate"] < lineitem["l_commitdate"])
         & (lineitem["l_receiptdate"] >= year_lo)
         & (lineitem["l_receiptdate"] < year_lo + 365))
    li = lineitem.select(m)
    omap = dict(zip(orders["o_orderkey"].tolist(),
                    orders["o_orderpriority"].tolist()))
    prio = np.asarray([omap.get(int(k), -1) for k in li["l_orderkey"]])
    keep = prio >= 0
    shipmode = li["l_shipmode"][keep]
    high = np.isin(prio[keep], [URGENT, HIGH]).astype(np.float64)
    uniq, inv = np.unique(shipmode, return_inverse=True)
    return ColumnBatch({
        "l_shipmode": uniq,
        "high_line_count": np.bincount(inv, weights=high,
                                       minlength=len(uniq)),
        "low_line_count": np.bincount(inv, weights=1.0 - high,
                                      minlength=len(uniq)),
    })


# ---------------------------------------------------------------------------
# TPCx-BB Q3 — MapReduce-style UDF job over clickstreams
# ---------------------------------------------------------------------------

def bb_q3_plan(item_table_key: str, target_category: int = 3,
               window: int = 5, shuffle_partitions: int = 8,
               top_k: int = 10) -> QueryPlan:
    map_pipe = Pipeline(
        name="map_clicks",
        input=TableInput("clickstreams", ["wcs_user_sk", "wcs_click_date_sk",
                                          "wcs_click_time_sk", "wcs_item_sk",
                                          "wcs_click_type"]),
        ops=[{"op": "udf", "name": "clicks_before_purchase",
              "kwargs": {"target_category": target_category,
                         "window": window},
              "broadcast": {"item_categories": {"key": item_table_key,
                                                "column": "i_category_id"}}}],
        output=ShuffleOutput(partition_by="viewed_item",
                             partitions=shuffle_partitions))
    reduce_pipe = Pipeline(
        name="reduce_counts",
        input=ShuffleInput("map_clicks"),
        ops=[{"op": "hash_agg", "keys": ["viewed_item"],
              "aggs": [["views", "sum", "n"]]}],
        output=CollectOutput())
    return QueryPlan("tpcxbb_q3", [map_pipe, reduce_pipe])


def bb_q3_reference(clicks: ColumnBatch, item: ColumnBatch,
                    target_category: int = 3, window: int = 5
                    ) -> dict[int, int]:
    order = np.lexsort((clicks["wcs_click_time_sk"],
                        clicks["wcs_click_date_sk"], clicks["wcs_user_sk"]))
    user = clicks["wcs_user_sk"][order]
    item_sk = clicks["wcs_item_sk"][order]
    ctype = clicks["wcs_click_type"][order]
    cats = item["i_category_id"]
    counts: dict[int, int] = {}
    for p in np.flatnonzero((ctype == PURCHASE)
                            & (cats[item_sk] == target_category)):
        lo = max(0, p - window)
        for j in range(lo, p):
            if user[j] == user[p] and ctype[j] == VIEW:
                counts[int(item_sk[j])] = counts.get(int(item_sk[j]), 0) + 1
    return counts


QUERY_BUILDERS = {
    "q1": q1_plan,
    "q6": q6_plan,
    "q12": q12_plan,
}
